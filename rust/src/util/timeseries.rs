//! Fixed-capacity ring buffer for metric histories (forecast windows etc).

/// Ring buffer of f64 samples with O(1) push and windowed reads.
#[derive(Debug, Clone)]
pub struct RingBuffer {
    buf: Vec<f64>,
    head: usize, // next write slot
    len: usize,
}

impl RingBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        RingBuffer {
            buf: vec![0.0; capacity],
            head: 0,
            len: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    pub fn push(&mut self, x: f64) {
        self.buf[self.head] = x;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    /// Oldest-to-newest copy of the window.
    pub fn to_vec(&self) -> Vec<f64> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(|i| self.buf[(start + i) % cap]).collect()
    }

    /// Oldest-to-newest copy, left-padded with `pad` to full capacity —
    /// the forecast artifacts need a fixed-shape window even during warmup.
    pub fn to_padded_vec(&self, pad: f64) -> Vec<f64> {
        let mut out = vec![pad; self.capacity() - self.len];
        out.extend(self.to_vec());
        out
    }

    /// Most recent sample.
    pub fn last(&self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        let cap = self.buf.len();
        Some(self.buf[(self.head + cap - 1) % cap])
    }

    /// Mean over the most recent `n` samples (or fewer during warmup).
    pub fn recent_mean(&self, n: usize) -> f64 {
        let v = self.to_vec();
        let take = n.min(v.len());
        if take == 0 {
            return 0.0;
        }
        v[v.len() - take..].iter().sum::<f64>() / take as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_and_wraps() {
        let mut rb = RingBuffer::new(3);
        assert!(rb.is_empty());
        rb.push(1.0);
        rb.push(2.0);
        assert_eq!(rb.to_vec(), vec![1.0, 2.0]);
        rb.push(3.0);
        assert!(rb.is_full());
        rb.push(4.0);
        assert_eq!(rb.to_vec(), vec![2.0, 3.0, 4.0]);
        assert_eq!(rb.last(), Some(4.0));
    }

    #[test]
    fn padded_window() {
        let mut rb = RingBuffer::new(4);
        rb.push(5.0);
        assert_eq!(rb.to_padded_vec(0.0), vec![0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn recent_mean_windows() {
        let mut rb = RingBuffer::new(5);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0] {
            rb.push(x);
        }
        // window is now [2..6]
        assert_eq!(rb.recent_mean(2), 5.5);
        assert_eq!(rb.recent_mean(100), 4.0);
        assert_eq!(RingBuffer::new(3).recent_mean(2), 0.0);
    }

    #[test]
    fn wraparound_stress_matches_naive() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mut rb = RingBuffer::new(7);
        let mut naive: Vec<f64> = Vec::new();
        for _ in 0..500 {
            let x = rng.f64();
            rb.push(x);
            naive.push(x);
            let want: Vec<f64> = naive.iter().rev().take(7).rev().cloned().collect();
            assert_eq!(rb.to_vec(), want);
        }
    }
}
