//! Azure-Functions-like trace synthesizer.
//!
//! The paper extracts inter-arrival times from the two-week Microsoft Azure
//! Functions 2019 trace (Shahrad et al., ATC'20), which is not shipped in
//! this environment. Per the substitution rule (DESIGN.md) we synthesize a
//! trace with the statistics the paper's evaluation relies on:
//!
//! * **steady, non-bursty** aggregate rate ("the extracted inter-arrival
//!   rates exhibit steady, non-bursty behavior", Sec. V-B);
//! * **periodic structure that evolves over time** — the property that
//!   motivates the Fourier predictor over histograms/ARIMA (Sec. III-A).
//!
//! The generator superimposes a few slowly-drifting harmonic components on
//! a base rate and draws Poisson arrivals from the resulting intensity —
//! i.e. an inhomogeneous Poisson process with quasi-periodic intensity.
//! Periods are scaled to minutes (not days) so a 60-minute experiment sees
//! several full cycles, matching how the paper's 60-minute runs window the
//! two-week trace. The real trace can be substituted via `Trace::from_csv`.

use crate::config::{secs, Micros};
use crate::util::rng::Rng;
use crate::workload::Trace;

#[derive(Debug, Clone)]
pub struct AzureLikeConfig {
    /// Mean arrival rate (req/s).
    pub base_rate: f64,
    /// (period_s, relative amplitude) of the harmonic components.
    pub harmonics: Vec<(f64, f64)>,
    /// Per-cycle random drift applied to periods (evolving periodicity).
    pub period_drift: f64,
    /// Small white-noise modulation of the intensity.
    pub noise: f64,
}

impl Default for AzureLikeConfig {
    fn default() -> Self {
        AzureLikeConfig {
            base_rate: 12.0,
            harmonics: vec![(600.0, 0.35), (300.0, 0.20), (170.0, 0.10)],
            period_drift: 0.02,
            noise: 0.05,
        }
    }
}

/// Generate an Azure-like steady periodic trace covering `duration`.
pub fn generate(cfg: &AzureLikeConfig, duration: Micros, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0xA2_0E_5EED);
    let end = duration as f64 / 1e6;
    // random initial phases + per-run period perturbation (evolving
    // periodicity across seeds/runs)
    let comps: Vec<(f64, f64, f64)> = cfg
        .harmonics
        .iter()
        .map(|&(period, amp)| {
            let p = period * (1.0 + rng.range_f64(-cfg.period_drift, cfg.period_drift));
            (p, amp, rng.range_f64(0.0, std::f64::consts::TAU))
        })
        .collect();

    let intensity = |t: f64, rng: &mut Rng| -> f64 {
        let mut mod_f = 1.0;
        for &(period, amp, phase) in &comps {
            mod_f += amp * (std::f64::consts::TAU * t / period + phase).sin();
        }
        let noisy = mod_f * (1.0 + rng.range_f64(-cfg.noise, cfg.noise));
        (cfg.base_rate * noisy).max(0.0)
    };

    // thinning (Lewis-Shedler) with a conservative majorant
    let max_amp: f64 = cfg.harmonics.iter().map(|h| h.1).sum::<f64>();
    let lambda_max = cfg.base_rate * (1.0 + max_amp) * (1.0 + cfg.noise) + 1e-9;
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exp(lambda_max);
        if t >= end {
            break;
        }
        if rng.f64() < intensity(t, &mut rng) / lambda_max {
            arrivals.push(secs(t));
        }
    }
    Trace::new(arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::secs;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&AzureLikeConfig::default(), secs(600.0), 1);
        let b = generate(&AzureLikeConfig::default(), secs(600.0), 1);
        assert_eq!(a.arrivals, b.arrivals);
    }

    #[test]
    fn mean_rate_close_to_base() {
        let t = generate(&AzureLikeConfig::default(), secs(3600.0), 2);
        let rate = t.mean_rate();
        assert!(
            (rate - 12.0).abs() < 2.0,
            "mean rate {rate} too far from base 12"
        );
    }

    #[test]
    fn is_steady_not_bursty() {
        // coefficient of variation of 1s bins stays moderate, and few bins
        // are empty — the opposite profile of the synthetic bursty trace
        let t = generate(&AzureLikeConfig::default(), secs(3600.0), 3);
        let bins = t.binned(secs(1.0));
        let mean = bins.iter().map(|&b| b as f64).sum::<f64>() / bins.len() as f64;
        let var = bins
            .iter()
            .map(|&b| (b as f64 - mean).powi(2))
            .sum::<f64>()
            / bins.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv < 1.0, "cv={cv} too bursty for an azure-like trace");
        let empty = bins.iter().filter(|&&b| b == 0).count() as f64 / bins.len() as f64;
        assert!(empty < 0.2, "{empty} of bins empty");
    }

    #[test]
    fn has_periodic_structure() {
        // the 600 s component must show up as autocorrelation of the
        // 1-second bin series at lag ~600
        let t = generate(&AzureLikeConfig::default(), secs(10800.0), 4);
        let bins: Vec<f64> = t.binned(secs(1.0)).iter().map(|&b| b as f64).collect();
        let mean = bins.iter().sum::<f64>() / bins.len() as f64;
        let auto = |lag: usize| -> f64 {
            let n = bins.len() - lag;
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..n {
                num += (bins[i] - mean) * (bins[i + lag] - mean);
            }
            for b in &bins {
                den += (b - mean).powi(2);
            }
            num / den
        };
        let at_period = auto(600);
        let off_period = auto(457); // incommensurate lag
        assert!(
            at_period > off_period + 0.03,
            "no periodicity: ac(600)={at_period:.3} ac(457)={off_period:.3}"
        );
    }

    #[test]
    fn zero_duration_is_empty() {
        let t = generate(&AzureLikeConfig::default(), 0, 5);
        assert!(t.is_empty());
    }
}
