//! The Fig. 1 motivation scenario: 50 requests with randomly distributed
//! arrival times against a cold platform, reproducing the ~8 cold starts
//! and the warm-container staircase of the paper's opening example.

use crate::config::{secs, Micros};
use crate::util::rng::Rng;
use crate::workload::Trace;

/// 50 arrivals uniformly spread over `span` (paper-like default: ~7 min,
/// which yields gaps long enough that a handful of overlapping requests
/// trigger fresh cold starts while most reuse warm containers).
pub fn generate(span: Micros, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0xF1_6001);
    let arrivals = (0..50)
        .map(|_| rng.range_u64(0, span.saturating_sub(1)))
        .collect();
    Trace::new(arrivals)
}

pub fn default_span() -> Micros {
    secs(420.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_fifty_requests() {
        let t = generate(default_span(), 7);
        assert_eq!(t.len(), 50);
        assert!(t.duration() < default_span());
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            generate(default_span(), 7).arrivals,
            generate(default_span(), 7).arrivals
        );
    }
}
