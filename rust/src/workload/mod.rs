//! Workload generation: arrival traces for the paper's two experiment
//! families (Sec. IV "Workload"), the Fig. 1 motivation scenario, and the
//! multi-tenant function layer ([`tenant`]).

pub mod azure;
pub mod fig1;
pub mod synthetic;
pub mod tenant;

use crate::config::Micros;

pub use tenant::{FunctionId, FunctionProfile, FunctionRegistry, TenantWorkload};

/// An arrival trace: sorted request arrival times (µs from experiment start).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub arrivals: Vec<Micros>,
}

impl Trace {
    pub fn new(mut arrivals: Vec<Micros>) -> Self {
        arrivals.sort_unstable();
        Trace { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    pub fn duration(&self) -> Micros {
        self.arrivals.last().copied().unwrap_or(0)
    }

    /// Per-interval arrival counts (the Prometheus invocation-rate series).
    pub fn binned(&self, dt: Micros) -> Vec<u32> {
        if self.arrivals.is_empty() {
            return Vec::new();
        }
        let bins = (self.duration() / dt + 1) as usize;
        let mut out = vec![0u32; bins];
        for &t in &self.arrivals {
            out[(t / dt) as usize] += 1;
        }
        out
    }

    /// Truncate to arrivals strictly before `end`.
    pub fn truncate(&self, end: Micros) -> Trace {
        Trace {
            arrivals: self
                .arrivals
                .iter()
                .copied()
                .take_while(|&t| t < end)
                .collect(),
        }
    }

    /// Mean arrival rate in requests/second over the span `[0, duration()]`.
    ///
    /// Convention: the observation window is taken to be `[0, last
    /// arrival]`, so leading silence counts against the rate and a
    /// single arrival at `t > 0` reports `1 / t` (not the degenerate 0
    /// the pre-fix version returned). A trace whose span is zero (empty,
    /// or only arrivals at `t == 0`) has no measurable window and
    /// reports 0. When the enclosing experiment window is known —
    /// trailing silence matters — prefer [`Trace::mean_rate_in`].
    pub fn mean_rate(&self) -> f64 {
        self.mean_rate_in(self.duration())
    }

    /// Mean arrival rate in requests/second over an explicit observation
    /// window (the experiment duration), robust to single-arrival traces
    /// and leading/trailing silence. A zero window reports 0.
    pub fn mean_rate_in(&self, window: Micros) -> f64 {
        if self.arrivals.is_empty() || window == 0 {
            return 0.0;
        }
        self.arrivals.len() as f64 / (window as f64 / 1e6)
    }

    /// Load a single-column CSV of arrival timestamps in seconds (the format
    /// we extract from the real Azure Functions trace when available).
    pub fn from_csv(text: &str) -> Result<Trace, String> {
        let mut arrivals = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with("arrival") {
                continue;
            }
            let secs: f64 = line
                .split(',')
                .next()
                .unwrap()
                .trim()
                .parse()
                .map_err(|_| format!("line {}: bad timestamp '{line}'", i + 1))?;
            if secs < 0.0 {
                return Err(format!("line {}: negative timestamp", i + 1));
            }
            arrivals.push((secs * 1e6).round() as Micros);
        }
        Ok(Trace::new(arrivals))
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("arrival_s\n");
        for &t in &self.arrivals {
            out.push_str(&format!("{:.6}\n", t as f64 / 1e6));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_arrivals() {
        let t = Trace::new(vec![30, 10, 20]);
        assert_eq!(t.arrivals, vec![10, 20, 30]);
        assert_eq!(t.duration(), 30);
    }

    #[test]
    fn binned_counts() {
        let t = Trace::new(vec![0, 500_000, 1_000_000, 1_200_000, 2_500_000]);
        assert_eq!(t.binned(1_000_000), vec![2, 2, 1]);
    }

    #[test]
    fn truncate_is_strict() {
        let t = Trace::new(vec![10, 20, 30]);
        assert_eq!(t.truncate(30).arrivals, vec![10, 20]);
    }

    #[test]
    fn mean_rate() {
        let t = Trace::new((0..=10).map(|i| i * 1_000_000).collect());
        assert!((t.mean_rate() - 1.1).abs() < 1e-9); // 11 requests over 10 s
    }

    #[test]
    fn mean_rate_window_convention() {
        // single arrival: rate over [0, t], not the degenerate 0
        let one = Trace::new(vec![2_000_000]);
        assert!((one.mean_rate() - 0.5).abs() < 1e-9);
        // zero span (empty, or only t == 0 arrivals) has no window
        assert_eq!(Trace::default().mean_rate(), 0.0);
        assert_eq!(Trace::new(vec![0]).mean_rate(), 0.0);
        // trailing silence: the explicit window sees it, mean_rate cannot
        let t = Trace::new((0..10).map(|i| i * 1_000_000).collect());
        assert!((t.mean_rate_in(20_000_000) - 0.5).abs() < 1e-9);
        assert!((t.mean_rate() - 10.0 / 9.0).abs() < 1e-9);
        assert_eq!(t.mean_rate_in(0), 0.0);
    }

    #[test]
    fn binned_arrival_on_exact_bin_boundary() {
        // an arrival at t == k*dt belongs to bin k (bins are [k*dt, (k+1)*dt))
        let dt = 1_000_000;
        let t = Trace::new(vec![0, dt, 2 * dt]);
        assert_eq!(t.binned(dt), vec![1, 1, 1]);
        // the last arrival exactly on a boundary still gets its own bin
        let t2 = Trace::new(vec![999_999, dt]);
        assert_eq!(t2.binned(dt), vec![1, 1]);
    }

    #[test]
    fn truncate_at_zero_is_empty() {
        let t = Trace::new(vec![0, 10, 20]);
        assert!(t.truncate(0).is_empty());
        assert_eq!(t.truncate(0).len(), 0);
    }

    #[test]
    fn binned_conserves_arrival_count() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("binned sum equals len", 200, |g| {
            let n = g.usize(0, 300);
            let arrivals: Vec<Micros> = (0..n).map(|_| g.u64(0, 5_000_000)).collect();
            let t = Trace::new(arrivals);
            let dt = g.u64(1, 2_000_000);
            let total: u64 = t.binned(dt).iter().map(|&c| c as u64).sum();
            prop_assert!(
                total == t.len() as u64,
                "dt={dt}: binned sum {total} != len {}",
                t.len()
            );
            Ok(())
        });
    }

    #[test]
    fn csv_roundtrip() {
        let t = Trace::new(vec![0, 1_500_000, 3_000_000]);
        let csv = t.to_csv();
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(back.arrivals, t.arrivals);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(Trace::from_csv("1.0\nnot-a-number\n").is_err());
        assert!(Trace::from_csv("-5\n").is_err());
        // comments and headers skipped
        let t = Trace::from_csv("# comment\narrival_s\n2.0\n").unwrap();
        assert_eq!(t.arrivals, vec![2_000_000]);
    }
}
