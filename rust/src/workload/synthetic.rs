//! Synthetic bursty workload (Sec. IV): "burst durations (1-5) s, idle
//! periods (50-800) s, and request rates (5-300) req/s", sampled uniformly.
//!
//! Arrivals inside a burst are Poisson at the sampled rate. A `scale`
//! parameter shrinks the idle-period range for quick tests while keeping
//! the burst structure (document any non-1.0 scale in reports).

use crate::config::{secs, Micros};
use crate::util::rng::Rng;
use crate::workload::Trace;

#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub burst_secs: (f64, f64),
    pub idle_secs: (f64, f64),
    pub rate_rps: (f64, f64),
    /// Multiplier on idle periods (1.0 = paper's ranges).
    pub idle_scale: f64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            burst_secs: (1.0, 5.0),
            idle_secs: (50.0, 800.0),
            rate_rps: (5.0, 300.0),
            idle_scale: 1.0,
        }
    }
}

/// Generate a bursty trace covering `duration`.
pub fn generate(cfg: &SyntheticConfig, duration: Micros, seed: u64) -> Trace {
    // distinct stream from the azure generator under equal seeds
    let mut rng = Rng::new(seed ^ STREAM_SALT);
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    let end = duration as f64 / 1e6;
    // start mid-idle so the first burst doesn't always hit t=0
    t += rng.range_f64(0.0, cfg.idle_secs.0 * cfg.idle_scale.max(0.01));
    while t < end {
        let burst_len = rng.range_f64(cfg.burst_secs.0, cfg.burst_secs.1);
        let rate = rng.range_f64(cfg.rate_rps.0, cfg.rate_rps.1);
        let burst_end = (t + burst_len).min(end);
        let mut at = t;
        loop {
            at += rng.exp(rate);
            if at >= burst_end {
                break;
            }
            arrivals.push(secs(at));
        }
        let idle = rng.range_f64(cfg.idle_secs.0, cfg.idle_secs.1) * cfg.idle_scale;
        t = burst_end + idle.max(0.001);
    }
    Trace::new(arrivals)
}

const STREAM_SALT: u64 = 0x5EED_B00C;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::secs;

    fn quick_cfg() -> SyntheticConfig {
        SyntheticConfig {
            idle_scale: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&quick_cfg(), secs(600.0), 1);
        let b = generate(&quick_cfg(), secs(600.0), 1);
        assert_eq!(a.arrivals, b.arrivals);
        let c = generate(&quick_cfg(), secs(600.0), 2);
        assert_ne!(a.arrivals, c.arrivals);
    }

    #[test]
    fn arrivals_within_duration() {
        let t = generate(&quick_cfg(), secs(600.0), 3);
        assert!(!t.is_empty());
        assert!(t.duration() <= secs(600.0));
    }

    #[test]
    fn burst_rates_in_configured_range() {
        // within any 1-second bin the rate should not wildly exceed the max
        let t = generate(&quick_cfg(), secs(1200.0), 4);
        let bins = t.binned(secs(1.0));
        let max = *bins.iter().max().unwrap();
        assert!(max as f64 <= 300.0 * 1.8, "bin max {max} too high");
    }

    #[test]
    fn is_actually_bursty() {
        // most 1-second bins are empty (long idle), some are dense
        let t = generate(&SyntheticConfig::default(), secs(3600.0), 5);
        let bins = t.binned(secs(1.0));
        let empty = bins.iter().filter(|&&b| b == 0).count() as f64;
        let frac_empty = empty / bins.len() as f64;
        assert!(frac_empty > 0.7, "only {frac_empty:.2} of bins empty");
        let peak = *bins.iter().max().unwrap();
        assert!(peak >= 5, "no real burst observed (peak={peak})");
    }

    #[test]
    fn idle_scale_shrinks_gaps() {
        let slow = generate(&SyntheticConfig::default(), secs(3600.0), 6);
        let fast = generate(&quick_cfg(), secs(3600.0), 6);
        assert!(fast.len() > slow.len());
    }
}
