//! Multi-tenant workload layer: function identities, per-function
//! profiles, Zipf popularity, and interleaved multi-function traces.
//!
//! The paper's Azure-trace experiments are inherently multi-tenant — the
//! MPC controller forecasts *per-function* invocations and jointly
//! optimizes prewarming and dispatch — but a single anonymous function
//! hides warm-pool fragmentation, cross-function contention, and
//! per-function tail latency. This module supplies the missing identity
//! layer:
//!
//! * [`FunctionId`] + [`FunctionProfile`] — per-function cold/warm
//!   latency, memory footprint, and keep-alive window;
//! * [`FunctionRegistry`] — the deployed function set (a one-entry
//!   registry reproduces the legacy single-tenant system exactly);
//! * [`zipf_shares`] — Azure-style heavy-tailed popularity (Shahrad et
//!   al., ATC'20 observe a small head of functions dominating
//!   invocations);
//! * [`TenantWorkload`] — per-function arrival traces interleaved into
//!   one merged trace, with the function of every request.
//!
//! Determinism: everything is a pure function of `(config, seed)`. With
//! `functions == 1` the generated workload is *bit-identical* to the
//! legacy single-tenant trace (same generator, same seed, every request
//! tagged function 0), which is what keeps all published figures valid.

use crate::cluster::image::{ImageManifest, Layer, LayerId};
use crate::config::{secs, Micros, PlatformConfig, TraceKind};
use crate::util::rng::Rng;
use crate::workload::{azure, synthetic, Trace};

/// Function (tenant) identifier: index into the [`FunctionRegistry`],
/// stable for a run. Function 0 is the paper's reference function.
pub type FunctionId = u32;

/// Per-function execution profile. Function 0 always carries the paper's
/// testbed constants; synthesized co-tenants vary around them.
#[derive(Debug, Clone)]
pub struct FunctionProfile {
    pub id: FunctionId,
    pub name: String,
    /// Warm execution latency of this function.
    pub l_warm: Micros,
    /// Cold-start initialization latency of this function.
    pub l_cold: Micros,
    /// Keep-alive window for this function's idle containers.
    pub keep_alive: Micros,
    /// Memory footprint of one container of this function (MiB).
    pub mem_mib: u32,
    /// Popularity share in (0, 1]; shares sum to 1 across the registry.
    pub share: f64,
    /// Per-function override of the retention planner's idle-cost rate
    /// (None = the global `KeepAliveConfig::idle_cost_per_s`). Lets a
    /// tenant declare its containers cheap/expensive to keep warm
    /// independently of the fleet-wide CLI knob.
    pub idle_cost: Option<f64>,
    /// Per-function override of the retention planner's cold-start cost
    /// weight (None = the global `KeepAliveConfig::cold_cost_weight`).
    pub cold_cost_weight: Option<f64>,
}

/// First app-layer id: ids below are reserved for base runtime layers
/// shared across every function's image.
const APP_LAYER_BASE: LayerId = 0x1000;
/// Base runtime layers every image shares (OS + language runtime): the
/// content-addressed overlap that makes one function's pull warm the
/// next function's cold start on the same node.
const BASE_LAYERS: [Layer; 2] = [
    Layer { id: 1, size_mib: 64 },   // OS base
    Layer { id: 2, size_mib: 192 },  // language runtime
];
/// Per-function code layer size (the top writable-ish layer).
const CODE_LAYER_MIB: u32 = 16;

impl FunctionProfile {
    /// The function's image manifest: the shared base runtime layers
    /// plus two function-private app layers (dependencies sized by the
    /// function's memory footprint — heavier functions ship heavier
    /// images — and a small code layer). Purely derived from the
    /// profile: no RNG, so adding the image model moves no seed stream.
    pub fn image(&self) -> ImageManifest {
        let deps = Layer {
            id: APP_LAYER_BASE + 2 * self.id as LayerId,
            size_mib: self.mem_mib,
        };
        let code = Layer {
            id: APP_LAYER_BASE + 2 * self.id as LayerId + 1,
            size_mib: CODE_LAYER_MIB,
        };
        let mut layers = BASE_LAYERS.to_vec();
        layers.push(deps);
        layers.push(code);
        ImageManifest::new(layers)
    }
}

/// The deployed function set. Cloned into every invoker node's platform
/// so container lifecycle latencies and keep-alive windows are
/// per-function.
#[derive(Debug, Clone)]
pub struct FunctionRegistry {
    profiles: Vec<FunctionProfile>,
}

impl FunctionRegistry {
    /// Build a registry from explicit profiles. Ids must equal their
    /// index (the registry is an arena keyed by [`FunctionId`]).
    pub fn new(profiles: Vec<FunctionProfile>) -> Self {
        assert!(!profiles.is_empty(), "registry needs at least one function");
        for (i, p) in profiles.iter().enumerate() {
            assert_eq!(p.id as usize, i, "profile ids must be their index");
        }
        FunctionRegistry { profiles }
    }

    /// One-function registry mirroring the platform config exactly — the
    /// legacy single-tenant system.
    pub fn single(pc: &PlatformConfig) -> Self {
        FunctionRegistry {
            profiles: vec![FunctionProfile {
                id: 0,
                name: "fn-0".to_string(),
                l_warm: pc.l_warm,
                l_cold: pc.l_cold,
                keep_alive: pc.keep_alive,
                mem_mib: pc.container_mem_mib,
                share: 1.0,
                idle_cost: None,
                cold_cost_weight: None,
            }],
        }
    }

    /// Synthesize `n` functions with Zipf(`zipf_s`) popularity shares.
    /// Function 0 keeps the paper profile; co-tenants draw deterministic
    /// variations (exec 150-450 ms, cold start 5-14 s, memory
    /// 128/256/384 MiB) from `seed` so every run is reproducible.
    pub fn synthesize(n: u32, zipf_s: f64, pc: &PlatformConfig, seed: u64) -> Self {
        let n = n.max(1);
        if n == 1 {
            return Self::single(pc);
        }
        let shares = zipf_shares(n, zipf_s);
        let mut rng = Rng::new(seed ^ PROFILE_SALT);
        let profiles = (0..n)
            .map(|id| {
                if id == 0 {
                    let mut p = Self::single(pc).profiles.remove(0);
                    p.share = shares[0];
                    return p;
                }
                FunctionProfile {
                    id,
                    name: format!("fn-{id}"),
                    l_warm: secs(rng.range_f64(0.150, 0.450)),
                    l_cold: secs(rng.range_f64(5.0, 14.0)),
                    keep_alive: pc.keep_alive,
                    mem_mib: *rng_pick(&mut rng, &[128, 256, 384]),
                    share: shares[id as usize],
                    // per-function break-even overrides are deployment
                    // metadata, not synthesized: None keeps the global
                    // knobs (and the profile RNG stream untouched)
                    idle_cost: None,
                    cold_cost_weight: None,
                }
            })
            .collect();
        FunctionRegistry { profiles }
    }

    pub fn get(&self, f: FunctionId) -> &FunctionProfile {
        &self.profiles[f as usize]
    }

    pub fn profiles(&self) -> &[FunctionProfile] {
        &self.profiles
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
}

const PROFILE_SALT: u64 = 0x7E4A_17F5;
const ASSIGN_SALT: u64 = 0x2F00_CAFE;
const TRACE_SALT: u64 = 0x51C6_D00D;

fn rng_pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.range_usize(0, xs.len() - 1)]
}

/// Zipf popularity shares over ranks 1..=n: the rank-r function's share
/// is ∝ 1/r^s, normalized to sum to 1. `s == 0` is uniform; the Azure
/// trace's head-heavy invocation distribution is around s ≈ 1.
pub fn zipf_shares(n: u32, s: f64) -> Vec<f64> {
    let n = n.max(1);
    let raw: Vec<f64> = (1..=n).map(|rank| (rank as f64).powf(-s)).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|w| w / total).collect()
}

/// Split an integer budget across functions proportionally to `shares`
/// (largest-remainder method): nothing is lost to rounding and the
/// result sums to `total` exactly. All-zero shares send the whole budget
/// to function 0 (the head function is the safest default target).
pub fn split_budget(shares: &[f64], total: u32) -> Vec<u32> {
    if shares.is_empty() {
        return Vec::new();
    }
    let sum: f64 = shares.iter().map(|s| s.max(0.0)).sum();
    if sum <= 0.0 {
        let mut out = vec![0u32; shares.len()];
        out[0] = total;
        return out;
    }
    let quotas: Vec<f64> = shares
        .iter()
        .map(|s| s.max(0.0) / sum * total as f64)
        .collect();
    let mut out: Vec<u32> = quotas.iter().map(|q| q.floor() as u32).collect();
    let assigned: u32 = out.iter().sum();
    // distribute the remainder by descending fractional part, ties to the
    // lower (more popular) index
    let mut frac: Vec<(f64, usize)> = quotas
        .iter()
        .enumerate()
        .map(|(i, q)| (q - q.floor(), i))
        .collect();
    // total_cmp: NaN quotas (degenerate shares driving 0/0 upstream) must
    // tie-break deterministically instead of panicking mid-generate
    frac.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    for k in 0..(total - assigned) as usize {
        out[frac[k % frac.len()].1] += 1;
    }
    out
}

/// A multi-function workload: the merged arrival sequence plus the
/// function of every request (request ids are assigned in merged arrival
/// order, matching the runner's convention).
#[derive(Debug, Clone)]
pub struct TenantWorkload {
    pub registry: FunctionRegistry,
    /// Merged arrival times, sorted ascending.
    pub arrivals: Vec<Micros>,
    /// Function of each arrival (empty ⇒ every request is function 0).
    pub funcs: Vec<FunctionId>,
}

impl TenantWorkload {
    /// Wrap a legacy single-tenant trace: one function (the platform
    /// profile), every arrival tagged function 0.
    pub fn single(trace: &Trace, pc: &PlatformConfig) -> Self {
        TenantWorkload {
            registry: FunctionRegistry::single(pc),
            arrivals: trace.arrivals.clone(),
            funcs: Vec::new(),
        }
    }

    /// Generate an `n`-function workload for `kind`.
    ///
    /// * `AzureLike`: each function gets its own quasi-periodic trace
    ///   (base rate scaled by its popularity share, independent phases
    ///   and period drift per function), merged by arrival time —
    ///   genuinely heterogeneous temporal structure, the case where
    ///   per-function forecasting matters.
    /// * `SyntheticBursty`: the aggregate burst profile of the paper is
    ///   preserved exactly (same generator, same seed as the
    ///   single-tenant trace) and each arrival is assigned a function by
    ///   popularity sampling — co-occurring bursts contended across
    ///   functions.
    ///
    /// With `n == 1` both arms reduce to the legacy single-tenant trace
    /// bit-for-bit.
    pub fn generate(
        kind: TraceKind,
        duration: Micros,
        seed: u64,
        n: u32,
        zipf_s: f64,
        pc: &PlatformConfig,
    ) -> Self {
        let registry = FunctionRegistry::synthesize(n, zipf_s, pc, seed);
        if registry.len() == 1 {
            return Self::single(&base_trace(kind, duration, seed), pc);
        }
        match kind {
            TraceKind::AzureLike => {
                let mut tagged: Vec<(Micros, FunctionId)> = Vec::new();
                for p in registry.profiles() {
                    let cfg = azure::AzureLikeConfig {
                        base_rate: azure::AzureLikeConfig::default().base_rate * p.share,
                        ..Default::default()
                    };
                    let fseed = seed ^ (p.id as u64).wrapping_mul(TRACE_SALT);
                    let t = azure::generate(&cfg, duration, fseed);
                    tagged.extend(t.arrivals.into_iter().map(|at| (at, p.id)));
                }
                tagged.sort_unstable();
                let (arrivals, funcs) = tagged.into_iter().unzip();
                TenantWorkload {
                    registry,
                    arrivals,
                    funcs,
                }
            }
            TraceKind::SyntheticBursty => {
                let trace = base_trace(kind, duration, seed);
                Self::assign(&trace, registry, seed)
            }
        }
    }

    /// Assign a function to every arrival of an existing trace by
    /// sampling the registry's popularity shares (deterministic in
    /// `seed`). Used for the bursty generator and for replayed
    /// `--trace-file` workloads.
    pub fn assign(trace: &Trace, registry: FunctionRegistry, seed: u64) -> Self {
        if registry.len() == 1 {
            return TenantWorkload {
                registry,
                arrivals: trace.arrivals.clone(),
                funcs: Vec::new(),
            };
        }
        let mut cum = Vec::with_capacity(registry.len());
        let mut acc = 0.0;
        for p in registry.profiles() {
            acc += p.share;
            cum.push(acc);
        }
        let mut rng = Rng::new(seed ^ ASSIGN_SALT);
        let last = registry.len() - 1;
        let funcs = trace
            .arrivals
            .iter()
            .map(|_| {
                let u = rng.f64() * acc;
                // clamp guards the float edge where u rounds up to acc
                cum.partition_point(|&c| c <= u).min(last) as FunctionId
            })
            .collect();
        TenantWorkload {
            registry,
            arrivals: trace.arrivals.clone(),
            funcs,
        }
    }

    /// Function of request `req` (requests are numbered in arrival order).
    pub fn func_of(&self, req: u64) -> FunctionId {
        self.funcs.get(req as usize).copied().unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// The merged (aggregate) trace.
    pub fn merged(&self) -> Trace {
        Trace {
            arrivals: self.arrivals.clone(),
        }
    }

    /// The arrival trace of one function.
    pub fn per_function(&self, f: FunctionId) -> Trace {
        Trace {
            arrivals: self
                .arrivals
                .iter()
                .enumerate()
                .filter(|&(i, _)| self.func_of(i as u64) == f)
                .map(|(_, &t)| t)
                .collect(),
        }
    }
}

/// The aggregate trace for a kind (mirrors `experiments::fig4::trace_for`
/// without the module cycle).
fn base_trace(kind: TraceKind, duration: Micros, seed: u64) -> Trace {
    match kind {
        TraceKind::AzureLike => azure::generate(&azure::AzureLikeConfig::default(), duration, seed),
        TraceKind::SyntheticBursty => {
            synthetic::generate(&synthetic::SyntheticConfig::default(), duration, seed)
        }
    }
}

/// Largest accepted Zipf exponent. Real workload skews sit well below
/// this; beyond it `rank.powf(-s)` underflows so hard that shares stop
/// being meaningfully distinct (and far past it, mixed over/underflow in
/// the normalization can produce 0/0 = NaN shares), so the CLI rejects
/// the spec up front instead of generating a degenerate workload.
pub const MAX_ZIPF_S: f64 = 64.0;

/// Parse a CLI skew spec: `uniform` or `zipf:<s>` with
/// `0 <= s <= MAX_ZIPF_S`. `None` (a structured CLI error upstream) for
/// anything else — including NaN, infinite, negative, or huge exponents
/// that would drive the share normalization degenerate.
pub fn parse_skew(s: &str) -> Option<f64> {
    if s == "uniform" {
        return Some(0.0);
    }
    let v: f64 = s.strip_prefix("zipf:")?.parse().ok()?;
    (v >= 0.0 && v <= MAX_ZIPF_S).then_some(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn pc() -> PlatformConfig {
        PlatformConfig::default()
    }

    #[test]
    fn single_registry_mirrors_platform_config() {
        let r = FunctionRegistry::single(&pc());
        assert_eq!(r.len(), 1);
        let p = r.get(0);
        assert_eq!(p.l_warm, pc().l_warm);
        assert_eq!(p.l_cold, pc().l_cold);
        assert_eq!(p.keep_alive, pc().keep_alive);
        assert_eq!(p.mem_mib, pc().container_mem_mib);
        assert_eq!(p.share, 1.0);
        // per-function break-even overrides default to the global knobs
        assert_eq!(p.idle_cost, None);
        assert_eq!(p.cold_cost_weight, None);
    }

    #[test]
    fn image_manifests_share_base_layers_and_scale_with_memory() {
        let r = FunctionRegistry::synthesize(4, 1.1, &pc(), 42);
        let imgs: Vec<ImageManifest> = r.profiles().iter().map(|p| p.image()).collect();
        for (p, img) in r.profiles().iter().zip(&imgs) {
            // base + deps + code, sized 64 + 192 + mem + 16
            assert_eq!(img.layers.len(), 4);
            assert_eq!(img.total_mib(), 64 + 192 + p.mem_mib as u64 + 16);
            assert_eq!(img.layers[0].id, 1);
            assert_eq!(img.layers[1].id, 2);
        }
        // base layers are content-identical across functions; app layers
        // are function-private
        for a in 0..imgs.len() {
            for b in (a + 1)..imgs.len() {
                assert_eq!(imgs[a].layers[0], imgs[b].layers[0]);
                assert_eq!(imgs[a].layers[1], imgs[b].layers[1]);
                assert_ne!(imgs[a].layers[2].id, imgs[b].layers[2].id);
                assert_ne!(imgs[a].layers[3].id, imgs[b].layers[3].id);
            }
        }
        // purely profile-derived: same registry, same manifests
        let again: Vec<ImageManifest> = r.profiles().iter().map(|p| p.image()).collect();
        assert_eq!(imgs, again);
    }

    #[test]
    fn synthesized_profiles_are_identical_with_and_without_image_model() {
        // deriving manifests consumes no RNG: the co-tenant profile
        // stream is exactly the pre-image-model stream
        let r = FunctionRegistry::synthesize(6, 1.1, &pc(), 42);
        for p in r.profiles() {
            let _ = p.image();
        }
        let again = FunctionRegistry::synthesize(6, 1.1, &pc(), 42);
        for (x, y) in r.profiles().iter().zip(again.profiles()) {
            assert_eq!(x.l_warm, y.l_warm);
            assert_eq!(x.l_cold, y.l_cold);
            assert_eq!(x.mem_mib, y.mem_mib);
        }
    }

    #[test]
    fn synthesized_registry_is_deterministic_and_headed_by_the_paper_profile() {
        let a = FunctionRegistry::synthesize(6, 1.1, &pc(), 42);
        let b = FunctionRegistry::synthesize(6, 1.1, &pc(), 42);
        assert_eq!(a.len(), 6);
        for (x, y) in a.profiles().iter().zip(b.profiles()) {
            assert_eq!(x.l_warm, y.l_warm);
            assert_eq!(x.l_cold, y.l_cold);
            assert_eq!(x.mem_mib, y.mem_mib);
            assert_eq!(x.share, y.share);
        }
        // function 0 keeps the paper constants
        assert_eq!(a.get(0).l_warm, pc().l_warm);
        assert_eq!(a.get(0).l_cold, pc().l_cold);
        // a different seed varies the co-tenants
        let c = FunctionRegistry::synthesize(6, 1.1, &pc(), 43);
        assert!(a
            .profiles()
            .iter()
            .zip(c.profiles())
            .skip(1)
            .any(|(x, y)| x.l_warm != y.l_warm || x.l_cold != y.l_cold));
    }

    #[test]
    fn zipf_shares_sum_to_one_and_decay() {
        for s in [0.0, 0.8, 1.1, 2.0] {
            let shares = zipf_shares(8, s);
            assert_eq!(shares.len(), 8);
            assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12, "s={s}");
            for w in shares.windows(2) {
                assert!(w[0] >= w[1], "shares must be non-increasing (s={s})");
            }
        }
        // uniform at s = 0
        let u = zipf_shares(4, 0.0);
        assert!(u.iter().all(|&x| (x - 0.25).abs() < 1e-12));
        // heavier skew concentrates the head
        assert!(zipf_shares(8, 2.0)[0] > zipf_shares(8, 1.1)[0]);
    }

    #[test]
    fn split_budget_conserves_total() {
        prop_check("split_budget sums to total", 200, |g| {
            let n = g.usize(1, 12);
            let shares = g.vec_f64(n, 0.0, 10.0);
            let total = g.u64(0, 200) as u32;
            let out = split_budget(&shares, total);
            prop_assert!(out.len() == n, "length mismatch");
            let sum: u32 = out.iter().sum();
            prop_assert!(sum == total, "sum {sum} != total {total}");
            Ok(())
        });
    }

    #[test]
    fn split_budget_follows_shares() {
        assert_eq!(split_budget(&[3.0, 1.0], 4), vec![3, 1]);
        assert_eq!(split_budget(&[1.0, 1.0, 1.0], 3), vec![1, 1, 1]);
        // all-zero shares default to function 0
        assert_eq!(split_budget(&[0.0, 0.0], 5), vec![5, 0]);
        assert_eq!(split_budget(&[], 5), Vec::<u32>::new());
        // largest remainder: 2.5 / 2.5 with 5 → 3 / 2 (tie to lower index)
        assert_eq!(split_budget(&[1.0, 1.0], 5), vec![3, 2]);
    }

    #[test]
    fn assignment_is_deterministic_by_seed() {
        let trace = base_trace(TraceKind::SyntheticBursty, secs(600.0), 7);
        let r = FunctionRegistry::synthesize(5, 1.1, &pc(), 7);
        let a = TenantWorkload::assign(&trace, r.clone(), 7);
        let b = TenantWorkload::assign(&trace, r.clone(), 7);
        assert_eq!(a.funcs, b.funcs);
        let c = TenantWorkload::assign(&trace, r, 8);
        assert_ne!(a.funcs, c.funcs, "different seed must reshuffle tenants");
        // every function id is in range
        assert!(a.funcs.iter().all(|&f| f < 5));
    }

    #[test]
    fn popularity_head_dominates_under_skew() {
        let trace = base_trace(TraceKind::SyntheticBursty, secs(3600.0), 11);
        let r = FunctionRegistry::synthesize(8, 1.1, &pc(), 11);
        let w = TenantWorkload::assign(&trace, r, 11);
        let f0 = w.per_function(0).len();
        let f7 = w.per_function(7).len();
        assert!(
            f0 > f7,
            "head function ({f0} arrivals) must outweigh the tail ({f7})"
        );
    }

    #[test]
    fn merged_equals_sum_of_per_function_traces() {
        for kind in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
            let w = TenantWorkload::generate(kind, secs(900.0), 13, 4, 1.1, &pc());
            let merged = w.merged();
            let dt = secs(60.0);
            let merged_bins = merged.binned(dt);
            let mut sum_bins = vec![0u32; merged_bins.len()];
            let mut total = 0;
            for f in 0..4 {
                let t = w.per_function(f);
                total += t.len();
                for (i, b) in t.binned(dt).iter().enumerate() {
                    sum_bins[i] += b;
                }
            }
            assert_eq!(total, merged.len(), "{kind:?}: arrival count conserved");
            assert_eq!(sum_bins, merged_bins, "{kind:?}: per-bin conservation");
        }
    }

    #[test]
    fn single_function_generation_is_bit_identical_to_legacy() {
        for kind in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
            let legacy = base_trace(kind, secs(1200.0), 42);
            let w = TenantWorkload::generate(kind, secs(1200.0), 42, 1, 1.1, &pc());
            assert_eq!(w.arrivals, legacy.arrivals, "{kind:?}");
            assert!(w.funcs.is_empty());
            assert_eq!(w.func_of(0), 0);
        }
    }

    #[test]
    fn generation_is_deterministic_by_seed() {
        let a = TenantWorkload::generate(TraceKind::AzureLike, secs(600.0), 3, 6, 1.1, &pc());
        let b = TenantWorkload::generate(TraceKind::AzureLike, secs(600.0), 3, 6, 1.1, &pc());
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.funcs, b.funcs);
        let c = TenantWorkload::generate(TraceKind::AzureLike, secs(600.0), 4, 6, 1.1, &pc());
        assert_ne!(a.arrivals, c.arrivals);
    }

    #[test]
    fn parse_skew_specs() {
        assert_eq!(parse_skew("uniform"), Some(0.0));
        assert_eq!(parse_skew("zipf:1.1"), Some(1.1));
        assert_eq!(parse_skew("zipf:0"), Some(0.0));
        assert_eq!(parse_skew("zipf:-1"), None);
        assert_eq!(parse_skew("zipf:"), None);
        assert_eq!(parse_skew("pareto:2"), None);
        // degenerate exponents are a structured CLI error, not a panic
        // further down in share normalization
        assert_eq!(parse_skew("zipf:64"), Some(MAX_ZIPF_S));
        assert_eq!(parse_skew("zipf:64.5"), None);
        assert_eq!(parse_skew("zipf:1e300"), None);
        assert_eq!(parse_skew("zipf:inf"), None);
        assert_eq!(parse_skew("zipf:nan"), None);
    }

    #[test]
    fn split_budget_survives_degenerate_shares() {
        // NaN shares are sanitized by the max(0.0) clamp (f64::max takes
        // the non-NaN operand) — the budget lands on the real shares
        assert_eq!(split_budget(&[f64::NAN, 1.0, 1.0], 10), vec![0, 5, 5]);
        // an *infinite* share is the panic path the old partial_cmp hit:
        // sum = inf, so its quota is inf/inf = NaN and reaches the
        // largest-remainder sort. total_cmp orders it; the budget still
        // sums exactly and nothing aborts.
        let out = split_budget(&[f64::INFINITY, 1.0], 10);
        assert_eq!(out.iter().sum::<u32>(), 10);
        // all-NaN clamps to all-zero: whole budget to function 0
        assert_eq!(split_budget(&[f64::NAN, f64::NAN], 7), vec![7, 0]);
    }
}
