//! Chaos-engine integration tests: the `--chaos off` bit-identical
//! regression that keeps every published figure valid (mirroring the
//! tenant/elasticity/keep-alive/image-cache inertness suites), the off
//! path's structural telemetry silence, preset determinism across
//! repeated runs and across the sharded engine, and the retry/timeout
//! counter conservation laws the fault injection must obey.

use mpc_serverless::config::{
    secs, ChaosConfig, ChaosMode, ExperimentConfig, Policy, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

fn cfg(duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

/// The full JSON surface with the only nondeterministic fields zeroed —
/// host-timing artifacts; every simulated quantity must reproduce byte
/// for byte.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.to_json().to_string()
}

/// Like [`canonical_json`] but also blind to the worker-thread count —
/// for comparing a sharded run against the sequential engine, where
/// `threads` is the one field that legitimately differs.
fn canonical_json_any_threads(mut r: RunReport) -> String {
    r.threads = 1;
    canonical_json(r)
}

fn workload_for(c: &ExperimentConfig) -> TenantWorkload {
    TenantWorkload::generate(
        c.trace,
        c.duration,
        c.seed,
        c.tenancy.functions,
        c.tenancy.zipf_s,
        &c.platform,
    )
}

/// The headline regression: `--chaos off` reproduces the seed-path
/// `RunReport` JSON byte-for-byte even with every chaos knob set to
/// aggressive values — with the mode off the engine is never
/// constructed, so no RNG stream moves and no probability can matter.
/// Pinned at `--nodes 1` (the legacy shape) and `--nodes 4
/// --functions 8` (the contended fleet), per the inertness-suite
/// pattern.
#[test]
fn chaos_off_is_bit_identical() {
    // knob values that would wreck every latency figure if anything
    // read them: 90% fault rates, 50x stragglers, hair-trigger timeouts
    let weird = ChaosConfig {
        mode: ChaosMode::Off,
        spawn_fail_p: 0.9,
        exec_fail_p: 0.9,
        straggler_p: 0.9,
        straggler_factor: 50.0,
        max_retries: 64,
        retry_backoff: secs(0.001),
        timeout_factor: 1.5,
    };
    // --nodes 1, single-tenant
    {
        let base = cfg(1200.0, 23, 1);
        let trace =
            mpc_serverless::experiments::fig4::trace_for(base.trace, base.duration, base.seed);
        let mut knobs = base.clone();
        knobs.chaos = weird;
        let a = run_experiment(&base, Policy::Mpc, &trace);
        let b = run_experiment(&knobs, Policy::Mpc, &trace);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "off mode must ignore the chaos knobs (--nodes 1)"
        );
    }
    // --nodes 4 --functions 8
    {
        let mut base = cfg(1200.0, 23, 8);
        base.fleet.nodes = 4;
        let w = workload_for(&base);
        let mut knobs = base.clone();
        knobs.chaos = weird;
        let a = run_tenant(&base, Policy::Mpc, &w);
        let b = run_tenant(&knobs, Policy::Mpc, &w);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "off mode must ignore the chaos knobs (--nodes 4 --functions 8)"
        );
    }
}

/// With chaos off, the new telemetry surface is structurally silent:
/// the retry/timeout/spawn-failure counters stay zero (aggregate and
/// per node) — nothing on the seed path can ever tick them.
#[test]
fn off_mode_report_is_silent_on_chaos_telemetry() {
    let mut c = cfg(900.0, 7, 4);
    c.fleet.nodes = 2;
    let w = workload_for(&c);
    let r = run_tenant(&c, Policy::Mpc, &w);
    assert!(r.completed > 0);
    assert_eq!(r.counters.retries, 0);
    assert_eq!(r.counters.timeouts, 0);
    assert_eq!(r.counters.spawn_failures, 0);
    for n in &r.per_node {
        assert_eq!(n.counters.retries, 0, "node {}", n.node);
        assert_eq!(n.counters.timeouts, 0, "node {}", n.node);
        assert_eq!(n.counters.spawn_failures, 0, "node {}", n.node);
    }
}

fn with_chaos(c: &ExperimentConfig, mode: ChaosMode) -> ExperimentConfig {
    let mut e = c.clone();
    e.chaos = ChaosConfig {
        mode,
        ..ChaosConfig::default()
    };
    e
}

/// Every preset × policy cell is deterministic: the same `(seed,
/// preset, policy)` reproduces the canonical report byte for byte
/// across repeated runs — the chaos RNG is one seeded stream rolled in
/// event order, and the preset schedules are pure functions of the
/// fleet shape. No cell may panic or wedge.
#[test]
fn presets_are_deterministic_under_every_policy() {
    let mut base = cfg(900.0, 11, 4);
    base.fleet.nodes = 4;
    let w = workload_for(&base);
    for mode in ChaosMode::PRESETS {
        let c = with_chaos(&base, mode);
        for policy in Policy::ALL {
            let a = run_tenant(&c, policy, &w);
            assert!(
                a.completed > 0,
                "{} under {} completed nothing",
                mode.name(),
                policy.name()
            );
            let b = run_tenant(&c, policy, &w);
            assert_eq!(
                canonical_json(a),
                canonical_json(b),
                "{} under {} is nondeterministic",
                mode.name(),
                policy.name()
            );
        }
    }
}

/// `--threads 2` under chaos matches the sequential engine exactly: the
/// chaos path forces the sharded engine's batch window to zero (the
/// fault handlers couple node-local work to the shared RNG stream and
/// cross-node retry placement), so the merge must replay the identical
/// event order.
#[test]
fn sharded_engine_matches_sequential_under_chaos() {
    let mut base = cfg(900.0, 11, 4);
    base.fleet.nodes = 4;
    let w = workload_for(&base);
    for mode in [ChaosMode::Faults, ChaosMode::FailureStorm] {
        let seq = with_chaos(&base, mode);
        let mut sharded = seq.clone();
        sharded.threads = 2;
        let a = run_tenant(&seq, Policy::Mpc, &w);
        let b = run_tenant(&sharded, Policy::Mpc, &w);
        assert_eq!(
            canonical_json_any_threads(a),
            canonical_json_any_threads(b),
            "{}: --threads 2 diverged from sequential",
            mode.name()
        );
    }
}

/// Counter conservation with a single fault kind enabled: every spawn
/// failure is answered by exactly one retry (none exhausts the budget
/// at these rates), no execution ever times out, the per-node counters
/// sum to the aggregate, and every request still completes.
#[test]
fn retry_counters_obey_conservation() {
    let mut c = cfg(900.0, 13, 4);
    c.fleet.nodes = 2;
    c.chaos = ChaosConfig {
        mode: ChaosMode::Faults,
        spawn_fail_p: 0.2,
        exec_fail_p: 0.0,
        straggler_p: 0.0,
        max_retries: 10,
        ..ChaosConfig::default()
    };
    let w = workload_for(&c);
    let r = run_tenant(&c, Policy::Mpc, &w);
    assert_eq!(r.dropped, 0, "a 10-retry budget at p=0.2 must never exhaust");
    assert_eq!(r.completed, w.len());
    assert!(r.counters.spawn_failures > 0, "p=0.2 over 900 s never fired");
    assert_eq!(
        r.counters.retries, r.counters.spawn_failures,
        "every spawn failure is answered by exactly one retry"
    );
    assert_eq!(r.counters.timeouts, 0, "no stragglers were injected");
    let sum = |f: fn(&mpc_serverless::cluster::Counters) -> u64| -> u64 {
        r.per_node.iter().map(|n| f(&n.counters)).sum()
    };
    assert_eq!(sum(|c| c.retries), r.counters.retries);
    assert_eq!(sum(|c| c.timeouts), r.counters.timeouts);
    assert_eq!(sum(|c| c.spawn_failures), r.counters.spawn_failures);
}
