//! CLI-level tests driving the built `mpc-serverless` binary: the
//! gen-trace → file → simulate --trace-file round trip, and the fleet
//! flags end-to-end.

use std::process::Command;

use mpc_serverless::util::json::Json;
use mpc_serverless::workload::Trace;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_mpc-serverless"))
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mpc-cli-{}-{}", std::process::id(), name))
}

#[test]
fn gen_trace_to_file_to_simulate_roundtrip() {
    let out = bin()
        .args(["gen-trace", "--trace", "synthetic", "--duration-s", "300", "--seed", "9"])
        .output()
        .expect("spawn gen-trace");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = String::from_utf8(out.stdout).unwrap();
    let trace = Trace::from_csv(&csv).expect("gen-trace emits parseable CSV");
    assert!(!trace.is_empty(), "empty generated trace");

    let path = tmp_path("roundtrip.csv");
    std::fs::write(&path, &csv).unwrap();

    let out = bin()
        .args([
            "simulate",
            "--policy",
            "openwhisk",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--trace-file",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn simulate");
    std::fs::remove_file(&path).ok();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).expect("report is JSON");
    // every request in the replayed file completes
    assert_eq!(
        report.path("completed").and_then(Json::as_f64),
        Some(trace.len() as f64),
        "{report:?}"
    );
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
}

#[test]
fn simulate_accepts_fleet_flags() {
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "openwhisk",
            "--trace",
            "synthetic",
            "--duration-s",
            "120",
            "--nodes",
            "8",
            "--placement",
            "warm-first",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("nodes").and_then(Json::as_f64), Some(8.0));
    assert_eq!(
        report.path("placement").and_then(Json::as_str),
        Some("warm-first")
    );
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
}

#[test]
fn simulate_rejects_bad_placement() {
    let out = bin()
        .args(["simulate", "--placement", "nope"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
}

#[test]
fn simulate_rejects_impossible_drain() {
    // failing the only node (or an out-of-range id) must be an error,
    // not a silent healthy run
    let out = bin()
        .args(["simulate", "--nodes", "1", "--fail-node", "0"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
    let out = bin()
        .args(["simulate", "--nodes", "4", "--fail-node", "9"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
}

#[test]
fn simulate_emits_per_function_breakdown() {
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "openwhisk",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--functions",
            "4",
            "--skew",
            "zipf:1.1",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
    // 300 s of the seed-9 bursty trace is non-empty (the roundtrip test
    // above pins that), so at least the head function saw traffic
    let n_funcs = report.path("functions").and_then(Json::as_f64).unwrap();
    assert!((1.0..=4.0).contains(&n_funcs), "{report:?}");
    let per_fn = report.path("per_function").unwrap().as_arr().unwrap();
    assert_eq!(per_fn.len() as f64, n_funcs);
    // per-function completions partition the aggregate
    let sum: f64 = per_fn
        .iter()
        .map(|f| f.path("completed").and_then(Json::as_f64).unwrap())
        .sum();
    assert_eq!(Some(sum), report.path("completed").and_then(Json::as_f64));
}

#[test]
fn simulate_rejects_bad_skew() {
    let out = bin()
        .args(["simulate", "--functions", "4", "--skew", "pareto:9"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
}

#[test]
fn simulate_accepts_adaptive_keepalive_flags() {
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "mpc",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--functions",
            "2",
            "--keepalive-policy",
            "adaptive",
            "--keepalive-min-s",
            "20",
            "--keepalive-idle-cost",
            "1.5",
            "--keepalive-cold-weight",
            "12",
            "--keepalive-pressure",
            "0.5",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(
        report.path("keepalive_policy").and_then(Json::as_str),
        Some("adaptive")
    );
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
    // the retention telemetry fields are on the JSON surface
    assert!(report.path("idle_saved_s").and_then(Json::as_f64).unwrap() >= 0.0);
    assert!(report.path("mean_horizon_s").and_then(Json::as_f64).unwrap() >= 0.0);
    assert!(report.path("adaptive_expiries").and_then(Json::as_f64).unwrap() >= 0.0);
    let per_fn = report.path("per_function").unwrap().as_arr().unwrap();
    assert!(per_fn
        .iter()
        .all(|f| f.path("mean_horizon_s").and_then(Json::as_f64).is_some()));
}

#[test]
fn simulate_rejects_bad_keepalive_flags() {
    // an unknown retention policy must be an error
    let out = bin()
        .args(["simulate", "--keepalive-policy", "nope"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
    // adaptive retention actuates from the MPC loop only
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "openwhisk",
            "--keepalive-policy",
            "adaptive",
        ])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
    // a non-positive floor must be rejected
    let out = bin()
        .args(["simulate", "--keepalive-policy", "adaptive", "--keepalive-min-s", "0"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
}

#[test]
fn keepalive_sweep_runs_end_to_end() {
    let out = bin()
        .args([
            "keepalive-sweep",
            "--duration-s",
            "180",
            "--seed",
            "9",
            "--functions",
            "2",
        ])
        .output()
        .expect("spawn keepalive-sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("keepalive-sweep:"), "{text}");
    // one fixed + one adaptive row per scenario, plus the frontier lines
    for needle in ["fixed", "adaptive", "bursty/1fn", "bursty/zipf", "azure/zipf"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
    assert!(text.contains("idle-time"), "no frontier verdict: {text}");
    // an invalid knob is rejected
    let out = bin()
        .args(["keepalive-sweep", "--keepalive-min-s", "-3"])
        .output()
        .expect("spawn keepalive-sweep");
    assert!(!out.status.success());
}

#[test]
fn simulate_accepts_image_cache_flags() {
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "mpc",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--functions",
            "2",
            "--nodes",
            "2",
            "--image-cache",
            "lru",
            "--image-cache-mib",
            "1024",
            "--image-bandwidth-mibps",
            "50",
            "--image-init-frac",
            "0.3",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
    // the cache telemetry is on the JSON surface and live: something
    // cold-started, so layers were pulled and dynamic costs were billed
    assert!(report.path("pull_mib").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(report.path("layer_misses").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(
        report
            .path("mean_effective_l_cold_s")
            .and_then(Json::as_f64)
            .unwrap()
            > 0.0
    );
}

#[test]
fn simulate_rejects_bad_image_cache_flags() {
    for args in [
        vec!["simulate", "--image-cache", "nope"],
        vec!["simulate", "--image-cache", "lru", "--image-cache-mib", "0"],
        vec!["simulate", "--image-bandwidth-mibps", "0"],
        vec!["simulate", "--image-init-frac", "1.5"],
    ] {
        let out = bin().args(&args).output().expect("spawn simulate");
        assert!(!out.status.success(), "{args:?} should be rejected");
    }
}

#[test]
fn simulate_restore_with_capacity_override_roundtrips() {
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "mpc",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--nodes",
            "4",
            "--fail-node",
            "1",
            "--fail-at-s",
            "60",
            "--restore-node",
            "1@120:8",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
    let per_node = report.path("per_node").unwrap().as_arr().unwrap();
    let caps: Vec<f64> = per_node
        .iter()
        .map(|n| n.path("capacity").and_then(Json::as_f64).unwrap())
        .collect();
    assert_eq!(caps[1], 8.0, "the restore cap must bind: {caps:?}");
    assert!(caps[0] > 8.0, "untouched nodes keep the default cap: {caps:?}");
    // a zero cap is a parse error
    let out = bin()
        .args([
            "simulate", "--nodes", "4", "--fail-node", "1", "--restore-node", "1@120:0",
        ])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
}

#[test]
fn cache_sweep_runs_end_to_end() {
    let out = bin()
        .args([
            "cache-sweep",
            "--duration-s",
            "180",
            "--seed",
            "9",
            "--nodes",
            "2",
            "--functions",
            "2",
            "--capacities-mib",
            "64,1024",
        ])
        .output()
        .expect("spawn cache-sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("cache-sweep:"), "{text}");
    // the off baseline row, both capacity rungs, and the frontier verdict
    for needle in ["off", "pulled MiB", "capacity 64 -> 1024 MiB"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
    // an invalid capacity ladder is rejected
    let out = bin()
        .args(["cache-sweep", "--capacities-mib", "256,0"])
        .output()
        .expect("spawn cache-sweep");
    assert!(!out.status.success());
}

#[test]
fn tenant_sweep_runs_end_to_end() {
    let out = bin()
        .args([
            "tenant-sweep",
            "--trace",
            "synthetic",
            "--duration-s",
            "180",
            "--functions",
            "3",
            "--skew",
            "zipf:1.1",
        ])
        .output()
        .expect("spawn tenant-sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("tenant-sweep:"), "{text}");
    for policy in ["openwhisk", "icebreaker", "mpc"] {
        assert!(text.contains(policy), "missing {policy} row: {text}");
    }
    assert!(text.contains("per-function P50/P99"), "{text}");
    assert!(text.contains("aggregate P99"), "{text}");
}

#[test]
fn simulate_rejects_zero_threads() {
    let out = bin()
        .args(["simulate", "--threads", "0"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success(), "--threads 0 must be rejected");
    let out = bin()
        .args(["simulate", "--threads", "nope"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success(), "--threads nope must be rejected");
}

#[test]
fn simulate_threads_roundtrip_and_reproduce_the_sequential_run() {
    let run = |threads: &str| {
        let out = bin()
            .args([
                "simulate",
                "--policy",
                "mpc",
                "--trace",
                "synthetic",
                "--duration-s",
                "300",
                "--seed",
                "9",
                "--nodes",
                "4",
                "--functions",
                "2",
                "--threads",
                threads,
            ])
            .output()
            .expect("spawn simulate");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        Json::parse(&String::from_utf8(out.stdout).unwrap()).expect("report is JSON")
    };
    let seq = run("1");
    let par = run("2");
    assert_eq!(seq.path("threads").and_then(Json::as_f64), Some(1.0));
    assert_eq!(par.path("threads").and_then(Json::as_f64), Some(2.0));
    // every simulated field must match across execution modes (only the
    // host-timing fields and the threads tag may move)
    for field in ["completed", "dropped", "mean_ms", "p99_ms", "cold_starts", "keepalive_total_s"] {
        assert_eq!(
            seq.path(field).and_then(Json::as_f64),
            par.path(field).and_then(Json::as_f64),
            "{field} diverged between --threads 1 and --threads 2"
        );
    }
}

#[test]
fn bench_throughput_accepts_a_threads_list() {
    let path = tmp_path("bench-threads.json");
    let out = bin()
        .args([
            "bench-throughput",
            "--duration-s",
            "60",
            "--seed",
            "9",
            "--nodes-list",
            "2",
            "--threads-list",
            "1,2",
            "--functions-list",
            "2",
            "--load-list",
            "1",
            "--out",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn bench-throughput");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("threads"), "no threads column: {text}");
    let json = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    let cells = json.path("cells").unwrap().as_arr().unwrap();
    let threads: Vec<f64> = cells
        .iter()
        .map(|c| c.path("threads").and_then(Json::as_f64).unwrap())
        .collect();
    assert_eq!(threads, vec![1.0, 2.0], "one cell per threads rung");
    // the simulated columns are bit-identical across the threads axis —
    // only the wall-clock columns may move
    for field in ["requests", "completed", "events", "p99_ms"] {
        assert_eq!(
            cells[0].path(field).and_then(Json::as_f64),
            cells[1].path(field).and_then(Json::as_f64),
            "{field} moved along the threads axis"
        );
    }
    // a zero entry in the list is a parse error
    let out = bin()
        .args(["bench-throughput", "--threads-list", "0,2"])
        .output()
        .expect("spawn bench-throughput");
    assert!(!out.status.success());
}

#[test]
fn simulate_forecast_flag_roundtrips_into_the_report() {
    // a fixed zoo backend lands in the report with structurally zero
    // selector telemetry
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "mpc",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--functions",
            "2",
            "--forecast",
            "histogram",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("forecast").and_then(Json::as_str), Some("histogram"));
    assert_eq!(report.path("selector_switches").and_then(Json::as_f64), Some(0.0));
    assert_eq!(report.path("dropped").and_then(Json::as_f64), Some(0.0));
    let per_fn = report.path("per_function").unwrap().as_arr().unwrap();
    assert!(per_fn
        .iter()
        .all(|f| f.path("forecast_model").and_then(Json::as_str) == Some("histogram")));
    // the auto selector with its knobs is accepted and tagged
    let out = bin()
        .args([
            "simulate",
            "--policy",
            "mpc",
            "--trace",
            "synthetic",
            "--duration-s",
            "300",
            "--seed",
            "9",
            "--forecast",
            "auto",
            "--forecast-window",
            "8",
            "--forecast-hysteresis",
            "0.2",
            "--forecast-warmup",
            "4",
        ])
        .output()
        .expect("spawn simulate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(report.path("forecast").and_then(Json::as_str), Some("auto"));
    assert!(report.path("selector_switches").and_then(Json::as_f64).unwrap() >= 0.0);
}

#[test]
fn simulate_rejects_bad_forecast_flags() {
    // an unknown backend must be an error
    let out = bin()
        .args(["simulate", "--forecast", "prophet"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
    // the zoo serves the MPC's forecasts only
    let out = bin()
        .args(["simulate", "--policy", "openwhisk", "--forecast", "auto"])
        .output()
        .expect("spawn simulate");
    assert!(!out.status.success());
    // selector knobs out of range
    for args in [
        vec!["simulate", "--forecast", "auto", "--forecast-window", "0"],
        vec!["simulate", "--forecast", "auto", "--forecast-hysteresis", "1.5"],
        vec!["simulate", "--forecast", "auto", "--forecast-warmup", "nope"],
    ] {
        let out = bin().args(&args).output().expect("spawn simulate");
        assert!(!out.status.success(), "{args:?} should be rejected");
    }
}

#[test]
fn forecast_sweep_runs_end_to_end() {
    let out = bin()
        .args([
            "forecast-sweep",
            "--duration-s",
            "1200",
            "--seed",
            "9",
            "--window",
            "24",
            "--horizon",
            "8",
        ])
        .output()
        .expect("spawn forecast-sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    // the envelope line pins the grid; every trace and backend shows up
    assert!(
        text.contains("forecast-sweep: traces=bursty,azure,diurnal backends=fourier,arima,histogram,attn,auto"),
        "{text}"
    );
    for needle in ["bursty", "azure", "diurnal", "fourier", "arima", "histogram", "attn", "auto", "switches"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
    // a duration too short for the rolling protocol is rejected up front
    let out = bin()
        .args(["forecast-sweep", "--duration-s", "100"])
        .output()
        .expect("spawn forecast-sweep");
    assert!(!out.status.success());
    // a degenerate window is rejected
    let out = bin()
        .args(["forecast-sweep", "--window", "1"])
        .output()
        .expect("spawn forecast-sweep");
    assert!(!out.status.success());
}

#[test]
fn fleet_sweep_runs_end_to_end() {
    let out = bin()
        .args([
            "fleet-sweep",
            "--policy",
            "openwhisk",
            "--trace",
            "synthetic",
            "--duration-s",
            "120",
            "--nodes-list",
            "1,2",
            "--placements",
            "round-robin,warm-first",
        ])
        .output()
        .expect("spawn fleet-sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    // 4 sweep cells + header rows
    assert!(text.contains("fleet-sweep:"), "{text}");
    assert!(text.contains("round-robin"), "{text}");
    assert!(text.contains("warm-first"), "{text}");
}
