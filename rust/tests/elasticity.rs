//! Fleet-elasticity integration tests: the drain → rejoin scenario end
//! to end, cross-node migration under the MPC control loop,
//! pressure-aware fleet reclaim, and the regression guard that a fleet
//! which never drains, rejoins, or migrates behaves exactly like the
//! pre-elasticity system (the new knobs are inert at their defaults —
//! the `--nodes 1` bit-identity anchor lives in `integration.rs`,
//! which compares against an inline reimplementation of the pre-fleet
//! event loop).

use mpc_serverless::cluster::Fleet;
use mpc_serverless::config::{
    secs, ExperimentConfig, FleetConfig, MigrationConfig, MigrationPolicy, NodeFailure,
    NodeRestore, PlacementPolicy, PlatformConfig, Policy, TraceKind,
};
use mpc_serverless::experiments::run_experiment;
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::synthetic::{generate, SyntheticConfig};
use mpc_serverless::workload::Trace;

fn cfg(nodes: u32, duration_s: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        fleet: FleetConfig {
            nodes,
            placement: PlacementPolicy::RoundRobin,
            ..Default::default()
        },
        duration: secs(duration_s),
        seed,
        ..Default::default()
    }
}

fn trace_for(c: &ExperimentConfig) -> Trace {
    generate(&SyntheticConfig::default(), c.duration, c.seed)
}

fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.completed, b.completed, "{ctx}: completed");
    assert_eq!(a.mean_ms, b.mean_ms, "{ctx}: mean");
    assert_eq!(a.p99_ms, b.p99_ms, "{ctx}: p99");
    assert_eq!(a.counters.cold_starts, b.counters.cold_starts, "{ctx}: cold");
    assert_eq!(a.warm_series, b.warm_series, "{ctx}: warm series");
    assert_eq!(a.keepalive_total_s, b.keepalive_total_s, "{ctx}: keepalive");
}

/// The headline acceptance scenario: a drained node rejoins mid-run and
/// must *reabsorb load* — nonzero post-restore dispatches and prewarms
/// in the per-node report. The control is the same run without the
/// restore, where the node stays dark and its post-drain activity is
/// exactly zero.
#[test]
fn restored_node_reabsorbs_load() {
    let node = 1u32;
    let mut with_restore = cfg(4, 1800.0, 7);
    with_restore.fleet.failures = vec![NodeFailure {
        node,
        at: secs(400.0),
    }];
    with_restore.fleet.restores = vec![NodeRestore {
        node,
        at: secs(800.0),
        cap: None,
    }];
    let trace = trace_for(&with_restore);
    let restored = run_experiment(&with_restore, Policy::Mpc, &trace);
    assert_eq!(restored.dropped, 0, "{restored:?}");
    assert_eq!(restored.completed, trace.len());

    let mut no_restore = with_restore.clone();
    no_restore.fleet.restores = Vec::new();
    let dark = run_experiment(&no_restore, Policy::Mpc, &trace);
    assert_eq!(dark.completed, trace.len());

    let post = |r: &RunReport| {
        r.per_node
            .iter()
            .find(|n| n.node == node)
            .expect("per-node report")
            .post_restore()
            .expect("the node drained, so the snapshot exists")
    };
    let dark_post = post(&dark);
    assert_eq!(dark_post.invocations, 0, "an offline node does no work");
    assert_eq!(dark_post.prewarms_started, 0);
    let rejoined = post(&restored);
    assert!(
        rejoined.invocations > 0,
        "rejoined node got no dispatches: {rejoined:?}"
    );
    assert!(
        rejoined.prewarms_started > 0,
        "rejoined node got no prewarm budget: {rejoined:?}"
    );
    // the rejoined node is back in the online report
    let nr = restored.per_node.iter().find(|n| n.node == node).unwrap();
    assert!(nr.online);
}

/// Heterogeneous restore (`--restore-node <id>@<t>:cap`): the node
/// rejoins after a hardware swap with a *different* replica cap. The
/// per-node report must show the overridden capacity binding on the
/// rejoined node (every other node keeps the default), and the node
/// must still reabsorb load end to end under the shrunk cap.
#[test]
fn restore_with_capacity_override_rebinds_the_reported_cap() {
    let node = 1u32;
    let mut c = cfg(4, 1800.0, 7);
    c.fleet.failures = vec![NodeFailure {
        node,
        at: secs(400.0),
    }];
    c.fleet.restores = vec![NodeRestore {
        node,
        at: secs(800.0),
        cap: Some(8),
    }];
    let trace = trace_for(&c);
    let r = run_experiment(&c, Policy::Mpc, &trace);
    assert_eq!(r.dropped, 0, "{r:?}");
    assert_eq!(r.completed, trace.len());
    for n in &r.per_node {
        if n.node == node {
            assert!(n.online);
            assert_eq!(n.capacity, 8, "the restore cap must bind: {n:?}");
        } else {
            assert_eq!(n.capacity, 64, "untouched nodes keep the default cap");
        }
    }
    let rejoined = r
        .per_node
        .iter()
        .find(|n| n.node == node)
        .unwrap()
        .post_restore()
        .expect("the node drained, so the snapshot exists");
    assert!(
        rejoined.invocations > 0,
        "capped rejoiner got no dispatches: {rejoined:?}"
    );
    // the cap is real: the node can never hold more than 8 containers,
    // so its post-restore container count in the final snapshot obeys it
    let nr = r.per_node.iter().find(|n| n.node == node).unwrap();
    assert!(nr.containers <= 8, "{nr:?}");
}

/// A rejoin shortly after the drain: Ready events for containers lost in
/// the drain arrive while the node is online again and must be dropped,
/// not panic — and every request still completes.
#[test]
fn stale_inflight_events_survive_an_early_rejoin() {
    let mut c = cfg(4, 1200.0, 11);
    c.fleet.failures = vec![NodeFailure {
        node: 2,
        at: secs(300.0),
    }];
    // restore inside the L_cold = 10.5 s window, so any cold start lost
    // at the drain has its stale Ready land on the rejoined node
    c.fleet.restores = vec![NodeRestore {
        node: 2,
        at: secs(305.0),
        cap: None,
    }];
    let trace = trace_for(&c);
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        assert_eq!(r.dropped, 0, "{}: {r:?}", r.policy);
        assert_eq!(r.completed, trace.len(), "{}", r.policy);
    }
}

/// Cross-node migration under the MPC control loop: with the drain →
/// rejoin scenario the survivors hold all warm capacity while the
/// rejoiner is cold, so the idle-spread pass must move containers —
/// conserving them fleet-wide (every migration-out lands as a
/// migration-in, nothing is double-counted as a cold start).
#[test]
fn migration_moves_warm_capacity_in_the_drain_scenario() {
    let mut c = cfg(4, 1800.0, 7);
    c.fleet.placement = PlacementPolicy::WarmFirst;
    c.fleet.failures = vec![NodeFailure {
        node: 1,
        at: secs(400.0),
    }];
    c.fleet.restores = vec![NodeRestore {
        node: 1,
        at: secs(800.0),
        cap: None,
    }];
    c.fleet.migration = MigrationConfig {
        policy: MigrationPolicy::IdleSpread,
        ..Default::default()
    };
    let trace = trace_for(&c);
    let r = run_experiment(&c, Policy::Mpc, &trace);
    assert_eq!(r.dropped, 0, "{r:?}");
    assert_eq!(r.completed, trace.len());
    assert!(
        r.counters.migrations_in > 0,
        "idle-spread never moved a container: {:?}",
        r.counters
    );
    assert_eq!(
        r.counters.migrations_in, r.counters.migrations_out,
        "fleet-wide migration conservation"
    );
    // demand-gap also runs the scenario to completion
    let mut dg = c.clone();
    dg.fleet.migration.policy = MigrationPolicy::DemandGap;
    let r2 = run_experiment(&dg, Policy::Mpc, &trace);
    assert_eq!(r2.dropped, 0);
    assert_eq!(r2.counters.migrations_in, r2.counters.migrations_out);
}

/// Pressure-aware reclaim at fleet level: with equal-scoring candidates
/// on both nodes, the memory-pressure bias must steer Algorithm 2's
/// cross-node pick toward the pressured node (and without the bias the
/// tie breaks to the lower node id, as before).
#[test]
fn fleet_reclaim_prefers_the_pressured_node() {
    let run = |weight: f64| {
        let pc = PlatformConfig {
            latency_jitter: 0.0,
            reclaim_pressure_weight: weight,
            ..Default::default()
        };
        let fc = FleetConfig {
            nodes: 2,
            ..Default::default()
        };
        let mut f = Fleet::new(&fc, &pc, 9);
        // one idle container on node 0, two on node 1 (more ledger
        // pressure); the *oldest* container on each node has the same
        // age, so the container scores tie exactly
        let (c0, r0) = f.node_mut(0).platform.prewarm_one(0).unwrap();
        f.node_mut(0).platform.container_ready(c0, r0);
        let (c1, r1) = f.node_mut(1).platform.prewarm_one(0).unwrap();
        f.node_mut(1).platform.container_ready(c1, r1);
        let (c2, r2) = f.node_mut(1).platform.prewarm_one(1_000_000).unwrap();
        f.node_mut(1).platform.container_ready(c2, r2);
        let got = f.try_reclaim(1, r2 + 5_000_000);
        assert_eq!(got.len(), 1);
        got[0].0
    };
    assert_eq!(run(0.0), 0, "unbiased tie breaks to the lower node id");
    assert_eq!(run(1.0), 1, "pressure bias steers reclaim to the loaded node");
}

/// Regression guard: the elasticity knobs are inert at their defaults.
/// With `MigrationPolicy::Off` the migration latency must not matter
/// (nothing reads it), no migrations happen, and no drain snapshots
/// exist — the pre-elasticity fleet behavior, bit for bit.
#[test]
fn elasticity_disabled_is_inert() {
    let base = cfg(4, 1200.0, 23);
    let trace = trace_for(&base);
    let mut weird_latency = base.clone();
    weird_latency.fleet.migration = MigrationConfig {
        policy: MigrationPolicy::Off,
        latency: secs(999.0),
        max_moves_per_step: 99,
    };
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let a = run_experiment(&base, policy, &trace);
        let b = run_experiment(&weird_latency, policy, &trace);
        assert_reports_identical(&a, &b, &format!("{policy:?}: Off must ignore its knobs"));
        assert_eq!(a.counters.migrations_in, 0);
        assert_eq!(a.counters.migrations_out, 0);
        assert!(
            a.per_node.iter().all(|n| n.post_restore().is_none()),
            "no node ever drained"
        );
    }
}
