//! Forecast model zoo integration tests: the `--forecast fourier`
//! bit-identical regression that keeps every published figure valid
//! (mirroring the keepalive/tenant inertness suites), the `auto`
//! selector's determinism — across repeated runs and across event-loop
//! shard counts — and the structural silence of the selector telemetry
//! under every fixed backend.

use mpc_serverless::config::{
    secs, ExperimentConfig, ForecastBackend, ForecastConfig, Policy, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

/// The full JSON surface with the only nondeterministic fields zeroed —
/// the simulator's own wall clock and the measured control-loop
/// overheads are host-timing artifacts; every simulated quantity must
/// reproduce byte for byte.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.to_json().to_string()
}

/// Selector knobs at deliberately aggressive values: under the fourier
/// backend every one of them must be completely inert.
fn weird_knobs() -> ForecastConfig {
    ForecastConfig {
        backend: ForecastBackend::Fourier,
        score_window: 2,
        hysteresis: 0.0,
        warmup_bins: 0,
    }
}

/// The headline regression: `--forecast fourier` (the default) plus
/// aggressive selector knobs reproduces the seed-path `RunReport` JSON
/// byte-for-byte. Pinned at `--nodes 1` (the legacy shape) and
/// `--nodes 4 --functions 8` (the contended fleet), per the pattern of
/// the keepalive/tenant inertness tests.
#[test]
fn forecast_fourier_is_bit_identical() {
    // --nodes 1, single-tenant
    {
        let base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 1);
        let trace =
            mpc_serverless::experiments::fig4::trace_for(base.trace, base.duration, base.seed);
        let mut knobs = base.clone();
        knobs.controller.forecast = weird_knobs();
        let a = run_experiment(&base, Policy::Mpc, &trace);
        let b = run_experiment(&knobs, Policy::Mpc, &trace);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "fourier backend must ignore the selector knobs (--nodes 1)"
        );
    }
    // --nodes 4 --functions 8
    {
        let mut base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 8);
        base.fleet.nodes = 4;
        let w = TenantWorkload::generate(
            base.trace,
            base.duration,
            base.seed,
            8,
            base.tenancy.zipf_s,
            &base.platform,
        );
        let mut knobs = base.clone();
        knobs.controller.forecast = weird_knobs();
        let a = run_tenant(&base, Policy::Mpc, &w);
        let b = run_tenant(&knobs, Policy::Mpc, &w);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "fourier backend must ignore the selector knobs (--nodes 4 --functions 8)"
        );
    }
}

fn with_backend(c: &ExperimentConfig, backend: ForecastBackend) -> ExperimentConfig {
    let mut a = c.clone();
    a.controller.forecast.backend = backend;
    a
}

/// A fixed-backend run carries structurally zero selector telemetry:
/// zero switches, zero rolling accuracy, every per-function row naming
/// the configured backend.
#[test]
fn fixed_backends_report_structurally_zero_selector_telemetry() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 7, 4);
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 4, 1.1, &c.platform);
    for backend in [
        ForecastBackend::Fourier,
        ForecastBackend::Arima,
        ForecastBackend::Histogram,
        ForecastBackend::Attn,
    ] {
        let r = run_tenant(&with_backend(&c, backend), Policy::Mpc, &w);
        assert_eq!(r.forecast, backend.name());
        assert_eq!(r.selector_switches, 0, "{}: fixed backends never switch", backend.name());
        assert!(!r.per_function.is_empty());
        for f in &r.per_function {
            assert_eq!(f.forecast_model, backend.name(), "fn {}", f.func);
            assert_eq!(f.forecast_accuracy_pct, 0.0, "fn {}", f.func);
        }
    }
}

/// The reactive baselines have no forecast registry: their reports keep
/// the structural defaults whatever the config says.
#[test]
fn reactive_policies_keep_the_default_forecast_surface() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 7, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let r = run_experiment(&c, Policy::OpenWhisk, &trace);
    assert_eq!(r.forecast, "fourier");
    assert_eq!(r.selector_switches, 0);
    assert!(r.per_function.iter().all(|f| f.forecast_model == "fourier"));
}

/// `--forecast auto` is deterministic: repeated runs on the same
/// workload reproduce the full canonical JSON surface — including the
/// selector's switch count and per-function model rows — byte for byte.
#[test]
fn auto_selector_is_self_deterministic() {
    let c = with_backend(
        &cfg(TraceKind::SyntheticBursty, 1800.0, 11, 4),
        ForecastBackend::Auto,
    );
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 4, 1.1, &c.platform);
    let a = run_tenant(&c, Policy::Mpc, &w);
    let b = run_tenant(&c, Policy::Mpc, &w);
    assert_eq!(a.forecast, "auto");
    assert_eq!(
        canonical_json(a),
        canonical_json(b),
        "auto selection must be a pure function of the realized bins"
    );
}

/// The selector's scoring loop rides the control tick, which is a
/// global event: sharded execution must reproduce the sequential run
/// byte for byte, switches and all.
#[test]
fn auto_selector_is_identical_under_threads() {
    let mut base = with_backend(
        &cfg(TraceKind::SyntheticBursty, 1800.0, 11, 8),
        ForecastBackend::Auto,
    );
    base.fleet.nodes = 4;
    let w = TenantWorkload::generate(base.trace, base.duration, base.seed, 8, 1.1, &base.platform);
    let seq = run_tenant(&base, Policy::Mpc, &w);
    let mut sharded = base.clone();
    sharded.threads = 2;
    let par = run_tenant(&sharded, Policy::Mpc, &w);
    // the threads field is stamped into the report; compare the rest
    let mut seq_canon = seq.clone();
    seq_canon.threads = 0;
    let mut par_canon = par.clone();
    par_canon.threads = 0;
    assert_eq!(
        canonical_json(seq_canon),
        canonical_json(par_canon),
        "--threads 2 must not perturb auto selection"
    );
    assert_eq!(seq.forecast, "auto");
}

/// The auto path keeps the run healthy: same completion set as the
/// fourier seed path on the same workload, with the telemetry naming a
/// zoo member per function.
#[test]
fn auto_run_completes_and_names_zoo_members() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 3, 4);
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 4, 1.1, &c.platform);
    let fourier = run_tenant(&c, Policy::Mpc, &w);
    let auto = run_tenant(&with_backend(&c, ForecastBackend::Auto), Policy::Mpc, &w);
    assert_eq!(auto.dropped, 0);
    assert_eq!(auto.completed, fourier.completed);
    let zoo = ["fourier", "arima", "histogram", "attn"];
    for f in &auto.per_function {
        assert!(
            zoo.contains(&f.forecast_model.as_str()),
            "fn {} routed through unknown model '{}'",
            f.func,
            f.forecast_model
        );
        assert!(
            (0.0..=100.0).contains(&f.forecast_accuracy_pct),
            "fn {} accuracy {} out of range",
            f.func,
            f.forecast_accuracy_pct
        );
    }
}
