//! Image-cache integration tests: the `--image-cache off` bit-identical
//! regression that keeps every published figure valid (mirroring the
//! tenant/elasticity/keep-alive inertness suites), the off path's
//! structural telemetry silence, and the enabled path's end-to-end
//! sanity — real pulls, real dynamic cold costs, same determinism
//! guarantees as the rest of the simulator.

use mpc_serverless::config::{
    secs, ExperimentConfig, ImageCacheConfig, ImageCacheMode, Policy, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

/// The full JSON surface with the only nondeterministic fields zeroed —
/// the simulator's own wall clock and the measured control-loop
/// overheads are host-timing artifacts; every simulated quantity must
/// reproduce byte for byte.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.to_json().to_string()
}

/// The headline regression: `--image-cache off` reproduces the
/// seed-path `RunReport` JSON byte-for-byte even with every cache knob
/// set to aggressive values — with the mode off, capacity, bandwidth,
/// and init fraction must be completely inert. Pinned at `--nodes 1`
/// (the legacy shape) and `--nodes 4 --functions 8` (the contended
/// fleet), per the pattern of the inertness suites.
#[test]
fn image_cache_off_is_bit_identical() {
    // a 1 MiB store, a 0.001 MiB/s registry link, and a 0.9 init slice
    // would wreck every latency figure if anything read them
    let weird = ImageCacheConfig {
        mode: ImageCacheMode::Off,
        capacity_mib: 1,
        bandwidth_mibps: 0.001,
        init_fraction: 0.9,
    };
    // --nodes 1, single-tenant
    {
        let base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 1);
        let trace =
            mpc_serverless::experiments::fig4::trace_for(base.trace, base.duration, base.seed);
        let mut knobs = base.clone();
        knobs.platform.image = weird;
        let a = run_experiment(&base, Policy::Mpc, &trace);
        let b = run_experiment(&knobs, Policy::Mpc, &trace);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "off mode must ignore the cache knobs (--nodes 1)"
        );
    }
    // --nodes 4 --functions 8
    {
        let mut base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 8);
        base.fleet.nodes = 4;
        let w = TenantWorkload::generate(
            base.trace,
            base.duration,
            base.seed,
            8,
            base.tenancy.zipf_s,
            &base.platform,
        );
        let mut knobs = base.clone();
        knobs.platform.image = weird;
        let a = run_tenant(&base, Policy::Mpc, &w);
        let b = run_tenant(&knobs, Policy::Mpc, &w);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "off mode must ignore the cache knobs (--nodes 4 --functions 8)"
        );
    }
}

/// With the cache off, the new telemetry surface is structurally silent:
/// every layer/pull/cost counter stays zero (aggregate and per node) and
/// the mean effective cold cost reports 0 — nothing on the seed path
/// ever touches the cache.
#[test]
fn off_mode_report_is_silent_on_cache_telemetry() {
    let mut c = cfg(TraceKind::SyntheticBursty, 900.0, 7, 4);
    c.fleet.nodes = 2;
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 4, 1.1, &c.platform);
    let r = run_tenant(&c, Policy::Mpc, &w);
    assert!(r.counters.cold_starts > 0, "scenario must exercise cold starts");
    assert_eq!(r.counters.layer_hits, 0);
    assert_eq!(r.counters.layer_misses, 0);
    assert_eq!(r.counters.pull_mib, 0);
    assert_eq!(r.counters.cold_cost_us, 0);
    assert_eq!(r.counters.cold_charges, 0);
    assert_eq!(r.counters.mean_effective_l_cold_s(), 0.0);
    for n in &r.per_node {
        assert_eq!(n.counters.layer_hits, 0, "node {}", n.node);
        assert_eq!(n.counters.layer_misses, 0, "node {}", n.node);
        assert_eq!(n.counters.pull_mib, 0, "node {}", n.node);
    }
}

fn with_cache(c: &ExperimentConfig, capacity_mib: u32) -> ExperimentConfig {
    let mut e = c.clone();
    e.platform.image = ImageCacheConfig {
        mode: ImageCacheMode::Lru,
        capacity_mib,
        ..ImageCacheConfig::default()
    };
    e
}

/// The enabled path end to end: cold starts bill dynamic per-node costs
/// (charges and pulled bytes are real), every cost the charging sites
/// billed sits inside the model's bounds — at least the init slice,
/// at most init + the full single-function image over the configured
/// link — and the run is as deterministic as the rest of the simulator.
#[test]
fn enabled_cache_bills_bounded_dynamic_costs_deterministically() {
    let mut c = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 8);
    c.fleet.nodes = 4;
    let e = with_cache(&c, 2048);
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 8, 1.1, &c.platform);
    let r = run_tenant(&e, Policy::Mpc, &w);
    assert_eq!(r.dropped, 0, "{r:?}");
    let ct = &r.counters;
    assert!(ct.cold_charges > 0, "{ct:?}");
    assert!(ct.pull_mib > 0, "cold images were never pulled: {ct:?}");
    assert!(ct.layer_misses > 0);
    // bounds of the cost model over the synthesized registry: the
    // smallest init-only slice (cache fully warm) up to the largest
    // init + whole-image pull over the configured link
    let ic = e.platform.image;
    let (mut floor_s, mut worst_s) = (f64::INFINITY, 0.0f64);
    for p in w.registry.profiles() {
        let init_s = ic.init_fraction * p.l_cold as f64 / 1e6;
        floor_s = floor_s.min(init_s);
        worst_s = worst_s.max(init_s + p.image().total_mib() as f64 / ic.bandwidth_mibps);
    }
    let mean_s = ct.mean_effective_l_cold_s();
    assert!(
        mean_s >= floor_s && mean_s <= worst_s,
        "mean effective L_cold {mean_s} outside [{floor_s}, {worst_s}]"
    );
    // determinism: same config + workload, byte-identical report
    let r2 = run_tenant(&e, Policy::Mpc, &w);
    assert_eq!(canonical_json(r), canonical_json(r2));
}
