//! Integration tests: full control loops over the platform substrate,
//! invariant audits, and HLO <-> Rust-mirror differential checks.

use mpc_serverless::config::{secs, ExperimentConfig, Policy, TraceKind};
use mpc_serverless::coordinator::controller::MpcScheduler;
use mpc_serverless::experiments::{fig4, run_experiment, run_with_scheduler};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::runtime::{ArtifactMeta, Engine, ForecastModule, HloForecaster, HloSolver, MpcModule};
use mpc_serverless::workload::synthetic::{generate, SyntheticConfig};
use mpc_serverless::workload::Trace;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        ..Default::default()
    }
}

fn audit(r: &RunReport, n_requests: usize) {
    assert_eq!(r.dropped, 0, "{}: dropped requests", r.policy);
    assert_eq!(r.completed, n_requests, "{}: completion count", r.policy);
    assert!(r.mean_ms >= 280.0 * 0.9, "{}: response below exec time", r.policy);
    assert!(r.response_times_s.iter().all(|t| t.is_finite() && *t >= 0.0));
    assert!(r.keepalive_total_s >= 0.0);
}

#[test]
fn all_policies_complete_the_azure_workload() {
    let c = cfg(TraceKind::AzureLike, 1200.0, 5);
    let trace = fig4::trace_for(TraceKind::AzureLike, c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        audit(&r, trace.len());
    }
}

#[test]
fn all_policies_complete_the_bursty_workload() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 9);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        audit(&r, trace.len());
    }
}

#[test]
fn mpc_reduces_cold_requests_on_bursty_load() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 13);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    let ow = run_experiment(&c, Policy::OpenWhisk, &trace);
    let mpc = run_experiment(&c, Policy::Mpc, &trace);
    assert!(
        mpc.cold_requests < ow.cold_requests,
        "MPC cold requests {} !< OW {}",
        mpc.cold_requests,
        ow.cold_requests
    );
    assert!(mpc.mean_warm < ow.mean_warm);
}

#[test]
fn capacity_is_never_exceeded() {
    // hammer a tiny platform; gauge samples must respect the replica cap
    let mut c = cfg(TraceKind::SyntheticBursty, 600.0, 21);
    c.platform.max_containers = 8;
    c.controller.weights.w_max = 8.0;
    c.sample_interval = secs(5.0);
    let trace = generate(
        &SyntheticConfig {
            idle_scale: 0.1,
            ..Default::default()
        },
        c.duration,
        c.seed,
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        for (t, w) in &r.warm_series {
            assert!(*w <= 8, "{}: {} warm at t={}", r.policy, w, t);
        }
        assert_eq!(r.dropped, 0, "{}", r.policy);
    }
}

#[test]
fn empty_and_single_request_traces() {
    let c = cfg(TraceKind::AzureLike, 120.0, 1);
    for trace in [Trace::default(), Trace::new(vec![secs(5.0)])] {
        for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
            let r = run_experiment(&c, policy, &trace);
            assert_eq!(r.completed, trace.len(), "{}", r.policy);
            assert_eq!(r.dropped, 0);
        }
    }
}

#[test]
fn hlo_backed_controller_matches_mirror_behaviour() {
    if !ArtifactMeta::available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = cfg(TraceKind::SyntheticBursty, 600.0, 31);
    let trace = generate(
        &SyntheticConfig {
            idle_scale: 0.2,
            ..Default::default()
        },
        c.duration,
        c.seed,
    );
    let mirror = run_experiment(&c, Policy::Mpc, &trace);

    let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
    let engine = Engine::cpu().unwrap();
    let sched = MpcScheduler::new(
        c.controller.clone(),
        Box::new(HloForecaster::new(
            ForecastModule::load(&engine, &meta).unwrap(),
            c.controller.gamma_clip as f32,
        )),
        Box::new(HloSolver::new(
            MpcModule::load(&engine, &meta).unwrap(),
            c.controller.weights,
        )),
    );
    let hlo = run_with_scheduler(&c, Box::new(sched), &trace);
    assert_eq!(hlo.completed, mirror.completed);
    assert_eq!(hlo.dropped, 0);
    // f32 vs f64 solver paths may schedule slightly differently; the
    // aggregate behaviour must stay close
    let rel = (hlo.mean_ms - mirror.mean_ms).abs() / mirror.mean_ms.max(1.0);
    assert!(rel < 0.35, "hlo mean {} vs mirror {}", hlo.mean_ms, mirror.mean_ms);
}

#[test]
fn runs_are_reproducible() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 17);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    let a = run_experiment(&c, Policy::Mpc, &trace);
    let b = run_experiment(&c, Policy::Mpc, &trace);
    assert_eq!(a.mean_ms, b.mean_ms);
    assert_eq!(a.counters.cold_starts, b.counters.cold_starts);
    assert_eq!(a.warm_series, b.warm_series);
}
