//! Integration tests: full control loops over the platform substrate,
//! invariant audits, and HLO <-> Rust-mirror differential checks.

use mpc_serverless::cluster::platform::{
    CompleteOutcome, InvokeOutcome, KeepAliveVerdict, Platform, ReadyOutcome,
};
use mpc_serverless::config::{
    secs, ExperimentConfig, NodeFailure, PlacementPolicy, Policy, TraceKind,
};
use mpc_serverless::coordinator::controller::MpcScheduler;
use mpc_serverless::experiments::runner::grace;
use mpc_serverless::experiments::{fig4, run_experiment, run_with_scheduler};
use mpc_serverless::metrics::{Recorder, RunReport};
use mpc_serverless::runtime::{ArtifactMeta, Engine, ForecastModule, HloForecaster, HloSolver, MpcModule};
use mpc_serverless::simulator::EventQueue;
use mpc_serverless::workload::synthetic::{generate, SyntheticConfig};
use mpc_serverless::workload::Trace;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        ..Default::default()
    }
}

fn audit(r: &RunReport, n_requests: usize) {
    assert_eq!(r.dropped, 0, "{}: dropped requests", r.policy);
    assert_eq!(r.completed, n_requests, "{}: completion count", r.policy);
    assert!(r.mean_ms >= 280.0 * 0.9, "{}: response below exec time", r.policy);
    assert!(r.response_times_s.iter().all(|t| t.is_finite() && *t >= 0.0));
    assert!(r.keepalive_total_s >= 0.0);
}

#[test]
fn all_policies_complete_the_azure_workload() {
    let c = cfg(TraceKind::AzureLike, 1200.0, 5);
    let trace = fig4::trace_for(TraceKind::AzureLike, c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        audit(&r, trace.len());
    }
}

#[test]
fn all_policies_complete_the_bursty_workload() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 9);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        audit(&r, trace.len());
    }
}

#[test]
fn mpc_reduces_cold_requests_on_bursty_load() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 13);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    let ow = run_experiment(&c, Policy::OpenWhisk, &trace);
    let mpc = run_experiment(&c, Policy::Mpc, &trace);
    assert!(
        mpc.cold_requests < ow.cold_requests,
        "MPC cold requests {} !< OW {}",
        mpc.cold_requests,
        ow.cold_requests
    );
    assert!(mpc.mean_warm < ow.mean_warm);
}

#[test]
fn capacity_is_never_exceeded() {
    // hammer a tiny platform; gauge samples must respect the replica cap
    let mut c = cfg(TraceKind::SyntheticBursty, 600.0, 21);
    c.platform.max_containers = 8;
    c.controller.weights.w_max = 8.0;
    c.sample_interval = secs(5.0);
    let trace = generate(
        &SyntheticConfig {
            idle_scale: 0.1,
            ..Default::default()
        },
        c.duration,
        c.seed,
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        for (t, w) in &r.warm_series {
            assert!(*w <= 8, "{}: {} warm at t={}", r.policy, w, t);
        }
        assert_eq!(r.dropped, 0, "{}", r.policy);
    }
}

#[test]
fn empty_and_single_request_traces() {
    let c = cfg(TraceKind::AzureLike, 120.0, 1);
    for trace in [Trace::default(), Trace::new(vec![secs(5.0)])] {
        for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
            let r = run_experiment(&c, policy, &trace);
            assert_eq!(r.completed, trace.len(), "{}", r.policy);
            assert_eq!(r.dropped, 0);
        }
    }
}

#[test]
fn hlo_backed_controller_matches_mirror_behaviour() {
    if !ArtifactMeta::available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = cfg(TraceKind::SyntheticBursty, 600.0, 31);
    let trace = generate(
        &SyntheticConfig {
            idle_scale: 0.2,
            ..Default::default()
        },
        c.duration,
        c.seed,
    );
    let mirror = run_experiment(&c, Policy::Mpc, &trace);

    let meta = ArtifactMeta::load(&ArtifactMeta::default_dir()).unwrap();
    let engine = Engine::cpu().unwrap();
    let sched = MpcScheduler::new(
        c.controller.clone(),
        Box::new(HloForecaster::new(
            ForecastModule::load(&engine, &meta).unwrap(),
            c.controller.gamma_clip as f32,
        )),
        Box::new(HloSolver::new(
            MpcModule::load(&engine, &meta).unwrap(),
            c.controller.weights,
        )),
    );
    let hlo = run_with_scheduler(&c, Box::new(sched), &trace);
    assert_eq!(hlo.completed, mirror.completed);
    assert_eq!(hlo.dropped, 0);
    // f32 vs f64 solver paths may schedule slightly differently; the
    // aggregate behaviour must stay close
    let rel = (hlo.mean_ms - mirror.mean_ms).abs() / mirror.mean_ms.max(1.0);
    assert!(rel < 0.35, "hlo mean {} vs mirror {}", hlo.mean_ms, mirror.mean_ms);
}

/// Reference implementation of the pre-fleet single-platform event loop
/// for the reactive OpenWhisk policy (dispatch on arrival, no control
/// ticks). The fleet with `--nodes 1` must reproduce this bit-for-bit —
/// the determinism regression that keeps every existing figure valid.
fn legacy_single_platform_openwhisk(cfg: &ExperimentConfig, trace: &Trace) -> RunReport {
    #[derive(Debug, Clone, Copy)]
    enum LEv {
        Arrival(u64),
        Ready(u64),
        Done(u64),
        Sample,
        KeepAlive(u64),
    }

    let mut platform = Platform::new(cfg.platform.clone(), cfg.seed ^ 0x9_1A7F0);
    let mut events: EventQueue<LEv> = EventQueue::new();
    let mut recorder = Recorder::new(trace.len());
    for (i, &t) in trace.arrivals.iter().enumerate() {
        events.push(t, LEv::Arrival(i as u64));
    }
    events.push(cfg.sample_interval, LEv::Sample);
    let cutoff = cfg.duration + grace();
    while let Some(s) = events.pop_until(cutoff) {
        let now = s.time;
        match s.event {
            LEv::Arrival(req) => {
                recorder.on_arrival(req, now);
                recorder.on_dispatch(req, now);
                match platform.invoke(req, now) {
                    InvokeOutcome::WarmStart { cid, done_at } => {
                        events.push(done_at, LEv::Done(cid));
                    }
                    InvokeOutcome::ColdStart { cid, ready_at } => {
                        recorder.on_cold(req);
                        events.push(ready_at, LEv::Ready(cid));
                    }
                    InvokeOutcome::AtCapacity => {}
                }
            }
            LEv::Ready(cid) => match platform.container_ready(cid, now) {
                ReadyOutcome::Started { done_at, .. } => events.push(done_at, LEv::Done(cid)),
                ReadyOutcome::Idle => {
                    events.push(now + cfg.platform.keep_alive, LEv::KeepAlive(cid));
                }
                ReadyOutcome::Respawned { .. } => {
                    unreachable!("single-tenant run cannot respawn")
                }
            },
            LEv::Done(cid) => {
                // single-tenant: respawn is structurally None
                let CompleteOutcome {
                    completed, next, ..
                } = platform.exec_complete(cid, now);
                recorder.on_complete(completed, now);
                match next {
                    Some((_req, done_at)) => events.push(done_at, LEv::Done(cid)),
                    None => events.push(now + cfg.platform.keep_alive, LEv::KeepAlive(cid)),
                }
            }
            LEv::Sample => {
                recorder.on_gauge(platform.gauge(now, 0));
                if now < cfg.duration {
                    events.push(now + cfg.sample_interval, LEv::Sample);
                }
            }
            LEv::KeepAlive(cid) => match platform.keepalive_check(cid, now) {
                KeepAliveVerdict::Recheck(t) => events.push(t, LEv::KeepAlive(cid)),
                KeepAliveVerdict::Expired | KeepAliveVerdict::NotApplicable => {}
            },
        }
    }
    let end = cutoff.max(events.now());
    let (keepalive, idle_totals) = platform.finalize(end);
    RunReport::from_recorder(
        "openwhisk",
        cfg.trace.name(),
        cfg.duration,
        &recorder,
        platform.counters,
        &keepalive,
        &idle_totals,
    )
}

#[test]
fn single_node_fleet_matches_legacy_single_platform_exactly() {
    for placement in PlacementPolicy::ALL {
        let mut c = cfg(TraceKind::SyntheticBursty, 1200.0, 23);
        c.fleet.placement = placement;
        let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
        let legacy = legacy_single_platform_openwhisk(&c, &trace);
        let fleet = run_experiment(&c, Policy::OpenWhisk, &trace);
        assert_eq!(fleet.completed, legacy.completed, "{placement:?}");
        assert_eq!(fleet.mean_ms, legacy.mean_ms, "{placement:?}");
        assert_eq!(fleet.p99_ms, legacy.p99_ms, "{placement:?}");
        assert_eq!(fleet.counters.cold_starts, legacy.counters.cold_starts);
        assert_eq!(fleet.counters.invocations, legacy.counters.invocations);
        assert_eq!(
            fleet.counters.keepalive_expiries,
            legacy.counters.keepalive_expiries
        );
        assert_eq!(fleet.warm_series, legacy.warm_series, "{placement:?}");
        assert_eq!(fleet.keepalive_total_s, legacy.keepalive_total_s);
        assert_eq!(fleet.idle_total_s, legacy.idle_total_s);
    }
}

#[test]
fn multi_node_fleet_with_mpc_completes_bursty_load() {
    let mut c = cfg(TraceKind::SyntheticBursty, 1800.0, 29);
    c.fleet.nodes = 8;
    c.fleet.placement = PlacementPolicy::WarmFirst;
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    let r = run_experiment(&c, Policy::Mpc, &trace);
    audit(&r, trace.len());
    assert_eq!(r.nodes, 8);
    assert_eq!(r.placement, "warm-first");
}

#[test]
fn node_drain_scenario_completes_all_requests() {
    // a quarter of the fleet dies mid-run; the backlog redistributes and
    // every request still completes on the survivors
    let mut c = cfg(TraceKind::SyntheticBursty, 1800.0, 31);
    c.fleet.nodes = 4;
    c.fleet.placement = PlacementPolicy::LeastLoaded;
    c.fleet.failures = vec![NodeFailure {
        node: 2,
        at: secs(700.0),
    }];
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        audit(&r, trace.len());
    }
    // the drain must actually change cluster behaviour vs a healthy
    // fleet: node 2's warm pool vanishes at the outage, so the warm
    // gauge series cannot stay identical
    let healthy = {
        let mut h = c.clone();
        h.fleet.failures = Vec::new();
        run_experiment(&h, Policy::OpenWhisk, &trace)
    };
    let drained = run_experiment(&c, Policy::OpenWhisk, &trace);
    assert_eq!(drained.completed, healthy.completed);
    assert_ne!(
        drained.warm_series, healthy.warm_series,
        "node outage left the warm-container series untouched"
    );
}

#[test]
fn runs_are_reproducible() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 17);
    let trace = generate(&SyntheticConfig::default(), c.duration, c.seed);
    let a = run_experiment(&c, Policy::Mpc, &trace);
    let b = run_experiment(&c, Policy::Mpc, &trace);
    assert_eq!(a.mean_ms, b.mean_ms);
    assert_eq!(a.counters.cold_starts, b.counters.cold_starts);
    assert_eq!(a.warm_series, b.warm_series);
}
