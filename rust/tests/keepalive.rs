//! Retention-control integration tests: the `--keepalive-policy fixed`
//! bit-identical regression that keeps every published figure valid
//! (mirroring the tenant/elasticity inertness suites), and the adaptive
//! planner's headline claim — strictly less idle resource-time on the
//! bursty workloads, with the earlier-than-profile expiries and horizon
//! trajectory visible in the report.

use mpc_serverless::config::{
    secs, ExperimentConfig, KeepAliveConfig, KeepAlivePolicy, Policy, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

/// The full JSON surface with the only nondeterministic fields zeroed —
/// the simulator's own wall clock and the measured control-loop
/// overheads are host-timing artifacts; every simulated quantity must
/// reproduce byte for byte.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.to_json().to_string()
}

/// The headline regression: `--keepalive-policy fixed` reproduces the
/// seed-path `RunReport` JSON byte-for-byte even with every adaptive
/// knob set to aggressive values — the knobs must be completely inert
/// under the fixed policy. Pinned at `--nodes 1` (the legacy shape) and
/// `--nodes 4 --functions 8` (the contended fleet), per the pattern of
/// the tenant/elasticity inertness tests.
#[test]
fn keepalive_fixed_is_bit_identical() {
    let weird = KeepAliveConfig {
        policy: KeepAlivePolicy::Fixed,
        min: secs(1.0),
        idle_cost_per_s: 99.0,
        cold_cost_weight: 0.001,
        pressure_weight: 7.0,
    };
    // --nodes 1, single-tenant
    {
        let base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 1);
        let trace =
            mpc_serverless::experiments::fig4::trace_for(base.trace, base.duration, base.seed);
        let mut knobs = base.clone();
        knobs.controller.keepalive = weird;
        let a = run_experiment(&base, Policy::Mpc, &trace);
        let b = run_experiment(&knobs, Policy::Mpc, &trace);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "fixed policy must ignore the adaptive knobs (--nodes 1)"
        );
    }
    // --nodes 4 --functions 8
    {
        let mut base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 8);
        base.fleet.nodes = 4;
        let w = TenantWorkload::generate(
            base.trace,
            base.duration,
            base.seed,
            8,
            base.tenancy.zipf_s,
            &base.platform,
        );
        let mut knobs = base.clone();
        knobs.controller.keepalive = weird;
        let a = run_tenant(&base, Policy::Mpc, &w);
        let b = run_tenant(&knobs, Policy::Mpc, &w);
        assert_eq!(
            canonical_json(a),
            canonical_json(b),
            "fixed policy must ignore the adaptive knobs (--nodes 4 --functions 8)"
        );
    }
}

/// A fixed-policy run carries no retention telemetry at all — the new
/// report surface is structurally silent on the seed path.
#[test]
fn fixed_policy_report_is_silent_on_retention() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 7, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let r = run_experiment(&c, Policy::Mpc, &trace);
    assert_eq!(r.keepalive_policy, "fixed");
    assert_eq!(r.idle_saved_s, 0.0);
    assert_eq!(r.mean_horizon_s, 0.0);
    assert_eq!(r.counters.adaptive_expiries, 0);
    assert!(r.per_function.iter().all(|f| f.mean_horizon_s == 0.0));
}

fn adaptive(c: &ExperimentConfig) -> ExperimentConfig {
    let mut a = c.clone();
    a.controller.keepalive.policy = KeepAlivePolicy::Adaptive;
    a
}

/// Adaptive config pinned at the unit-tested degenerate corner: a zero
/// cold-cost weight makes the break-even rate unbeatable, so every
/// horizon clamps to the floor *deterministically* — the strongest
/// retention the planner can apply, independent of what the Fourier
/// forecast happens to predict on this trace. The strict resource-time
/// assertions below use it so they pin the retention *machinery* (live
/// horizons, sweeps, accounting) rather than a forecast-calibration
/// coincidence; the tuned default-knob frontier is what
/// `keepalive-sweep` / `benches/fig13_keepalive.rs` report.
fn floor_clamped(c: &ExperimentConfig) -> ExperimentConfig {
    let mut a = adaptive(c);
    a.controller.keepalive.cold_cost_weight = 0.0;
    a
}

/// The resource-time claim on the bursty single-tenant workload: with
/// the horizon at the 30 s floor, idle containers are released during
/// the 50-800 s inter-burst gaps the fixed 10-minute window idles
/// through — strictly less idle resource-time, every request still
/// completes, and the savings are earlier-than-profile expiries.
#[test]
fn adaptive_cuts_idle_resource_time_on_bursty_single_tenant() {
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 3, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let fixed = run_experiment(&c, Policy::Mpc, &trace);
    let adapt = run_experiment(&floor_clamped(&c), Policy::Mpc, &trace);
    assert_eq!(fixed.dropped, 0);
    assert_eq!(adapt.dropped, 0);
    assert_eq!(adapt.completed, fixed.completed);
    assert!(
        adapt.idle_total_s < fixed.idle_total_s,
        "adaptive idle {} !< fixed {}",
        adapt.idle_total_s,
        fixed.idle_total_s
    );
    assert!(
        adapt.counters.adaptive_expiries > 0,
        "no earlier-than-profile expiries: {:?}",
        adapt.counters
    );
    assert!(adapt.idle_saved_s > 0.0);
}

/// Same claim on the Zipf multi-tenant bursty workload (the contended
/// scenario the sweep's acceptance criterion names): the tail functions'
/// idle containers are the first retention releases.
#[test]
fn adaptive_cuts_idle_resource_time_on_zipf_multi_tenant() {
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 3, 8);
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 8, 1.1, &c.platform);
    let fixed = run_tenant(&c, Policy::Mpc, &w);
    let adapt = run_tenant(&floor_clamped(&c), Policy::Mpc, &w);
    assert_eq!(fixed.dropped, 0);
    assert_eq!(adapt.dropped, 0);
    assert_eq!(adapt.completed, fixed.completed);
    assert!(
        adapt.idle_total_s < fixed.idle_total_s,
        "adaptive idle {} !< fixed {}",
        adapt.idle_total_s,
        fixed.idle_total_s
    );
    assert!(adapt.counters.adaptive_expiries > 0, "{:?}", adapt.counters);
}

/// Default-knob adaptive retention completes the same workload as the
/// fixed baseline, and its savings accounting is internally consistent:
/// positive idle-time saved if and only if some expiry fired before its
/// profile window (how often that happens is forecast-calibration, the
/// sweep's business — not a pass/fail invariant).
#[test]
fn default_knob_adaptive_run_is_healthy_and_consistent() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 3, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let fixed = run_experiment(&c, Policy::Mpc, &trace);
    let adapt = run_experiment(&adaptive(&c), Policy::Mpc, &trace);
    assert_eq!(adapt.dropped, 0);
    assert_eq!(adapt.completed, fixed.completed);
    // idle saved is exactly the accounting of earlier-than-profile
    // expiries, so the pair moves together
    assert_eq!(adapt.idle_saved_s > 0.0, adapt.counters.adaptive_expiries > 0);
}

/// The adaptive report exposes the horizon trajectory, bounded by the
/// configured floor and the profile windows.
#[test]
fn adaptive_horizon_telemetry_is_bounded_and_present() {
    let c = cfg(TraceKind::SyntheticBursty, 1800.0, 11, 4);
    let a = adaptive(&c);
    let w = TenantWorkload::generate(c.trace, c.duration, c.seed, 4, 1.1, &c.platform);
    let r = run_tenant(&a, Policy::Mpc, &w);
    assert_eq!(r.keepalive_policy, "adaptive");
    let min_s = a.controller.keepalive.min as f64 / 1e6;
    let max_s = c.platform.keep_alive as f64 / 1e6;
    assert!(
        r.mean_horizon_s >= min_s && r.mean_horizon_s <= max_s,
        "mean horizon {} outside [{min_s}, {max_s}]",
        r.mean_horizon_s
    );
    for f in &r.per_function {
        assert!(
            f.mean_horizon_s >= min_s && f.mean_horizon_s <= max_s,
            "fn {} horizon {} outside [{min_s}, {max_s}]",
            f.func,
            f.mean_horizon_s
        );
    }
    // determinism: the adaptive path is as reproducible as the rest
    let r2 = run_tenant(&a, Policy::Mpc, &w);
    assert_eq!(r.mean_ms, r2.mean_ms);
    assert_eq!(r.idle_saved_s, r2.idle_saved_s);
    assert_eq!(r.counters.adaptive_expiries, r2.counters.adaptive_expiries);
}
