//! Sharded-engine differential suite: `--threads N` is an execution-mode
//! flag, not a modeling knob, so every simulated quantity in the
//! `RunReport` JSON must reproduce the sequential loop byte for byte —
//! across policies, fleet sizes, thread counts (including threads >
//! nodes), and with every optional subsystem (migration, adaptive
//! keep-alive, image cache) switched on at once. Only the host-timing
//! fields and the `threads` tag itself may differ between modes.

use mpc_serverless::config::{
    secs, ExperimentConfig, ImageCacheMode, KeepAlivePolicy, MigrationPolicy, Policy,
    TenantConfig, TraceKind,
};
use mpc_serverless::experiments::run_tenant;
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

const POLICIES: [Policy; 3] = [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc];
/// The sharded counts under test; each is compared against `--threads 1`.
const THREADS: [u32; 3] = [2, 4, 8];

fn cfg(nodes: u32, seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig {
        trace: TraceKind::SyntheticBursty,
        duration: secs(600.0),
        seed,
        tenancy: TenantConfig {
            functions: 8,
            zipf_s: 1.1,
        },
        ..Default::default()
    };
    c.fleet.nodes = nodes;
    c
}

fn with_threads(c: &ExperimentConfig, n: u32) -> ExperimentConfig {
    let mut t = c.clone();
    t.threads = n;
    t
}

fn workload(c: &ExperimentConfig) -> TenantWorkload {
    TenantWorkload::generate(
        c.trace,
        c.duration,
        c.seed,
        c.tenancy.functions,
        c.tenancy.zipf_s,
        &c.platform,
    )
}

/// The full JSON surface with the host-timing artifacts zeroed (same
/// pinning as `tests/keepalive.rs`) plus the `threads` tag — the one
/// simulated-state-free field that legitimately differs between the two
/// execution modes.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.threads = 0;
    r.to_json().to_string()
}

/// The headline differential: threads {2, 4, 8} × nodes {4, 16, 64} ×
/// all three policies, each cell byte-compared against the sequential
/// run of the same workload. The nodes-4 column exercises threads >
/// nodes (some shard workers get no nodes at all).
#[test]
fn sharded_matches_sequential_across_policies_and_fleet_sizes() {
    for nodes in [4u32, 16, 64] {
        let base = cfg(nodes, 29);
        let w = workload(&base);
        for policy in POLICIES {
            let seq = canonical_json(run_tenant(&base, policy, &w));
            for threads in THREADS {
                let r = run_tenant(&with_threads(&base, threads), policy, &w);
                assert_eq!(
                    r.threads, threads,
                    "report must record the requested thread count"
                );
                assert_eq!(
                    canonical_json(r),
                    seq,
                    "sharded run diverged: {policy:?}, {nodes} nodes, {threads} threads"
                );
            }
        }
    }
}

/// Every optional subsystem at once — forecast-driven migration,
/// adaptive keep-alive, LRU image cache — under MPC. These are the
/// subsystems whose state the control step must observe exactly as the
/// sequential loop left it, so this is the strongest barrier test.
#[test]
fn sharded_matches_sequential_with_every_subsystem_enabled() {
    let mut base = cfg(16, 31);
    base.fleet.migration.policy = MigrationPolicy::DemandGap;
    base.controller.keepalive.policy = KeepAlivePolicy::Adaptive;
    base.platform.image.mode = ImageCacheMode::Lru;
    let w = workload(&base);
    let seq = canonical_json(run_tenant(&base, Policy::Mpc, &w));
    for threads in THREADS {
        let par = run_tenant(&with_threads(&base, threads), Policy::Mpc, &w);
        assert_eq!(
            canonical_json(par),
            seq,
            "all-subsystems run diverged at {threads} threads"
        );
    }
}

/// `--threads` round-trips into the report (default 1 on the seed path)
/// and onto the JSON surface.
#[test]
fn report_records_the_thread_count() {
    let base = cfg(4, 5);
    let w = workload(&base);
    let r1 = run_tenant(&base, Policy::Mpc, &w);
    assert_eq!(r1.threads, 1, "sequential default must report threads=1");
    let r4 = run_tenant(&with_threads(&base, 4), Policy::Mpc, &w);
    assert_eq!(r4.threads, 4);
    let j = r4.to_json().to_string();
    assert!(j.contains("\"threads\""), "threads missing from JSON: {j}");
}

/// The sharded path is as reproducible as the sequential one: two runs
/// of the same config must agree on every byte, independent of OS
/// thread scheduling (the commit-time merge, not arrival order, decides
/// event ordering).
#[test]
fn sharded_run_is_self_deterministic() {
    let base = with_threads(&cfg(16, 13), 8);
    let w = workload(&base);
    let a = run_tenant(&base, Policy::Mpc, &w);
    let b = run_tenant(&base, Policy::Mpc, &w);
    assert_eq!(
        canonical_json(a),
        canonical_json(b),
        "sharded runs of identical configs diverged"
    );
}
