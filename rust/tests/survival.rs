//! Slot-survival policy integration tests: the differential regression
//! (every pre-existing policy reproduces its `RunReport` JSON byte for
//! byte with the survival knobs set — they are inert off-policy), the
//! survival policy's own determinism across repeated runs and
//! `--threads 2`, and the release-credit conservation law tying the
//! scheduler's release counter to the platform's expiry accounting.

use mpc_serverless::config::{
    secs, ExperimentConfig, Policy, SurvivalConfig, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::TenantWorkload;

fn cfg(kind: TraceKind, duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

/// The full JSON surface with the only nondeterministic fields zeroed —
/// the simulator's own wall clock and the measured control-loop
/// overheads are host-timing artifacts; every simulated quantity must
/// reproduce byte for byte.
fn canonical_json(mut r: RunReport) -> String {
    r.wall_clock_ms = 0.0;
    r.events_per_sec = 0.0;
    r.forecast_overhead_ms = 0.0;
    r.solve_overhead_ms = 0.0;
    r.to_json().to_string()
}

/// `canonical_json` with the thread count also normalized, for
/// cross-thread-count byte-identity (the report records the requested
/// `--threads`, which legitimately differs).
fn canonical_json_any_threads(mut r: RunReport) -> String {
    r.threads = 1;
    canonical_json(r)
}

/// Aggressive off-default estimator knobs for the inertness checks.
fn weird_knobs() -> SurvivalConfig {
    SurvivalConfig {
        window: 3,
        threshold: 0.99,
        min_samples: 1,
    }
}

/// The differential regression the acceptance criteria name: every
/// pre-existing policy must reproduce its `RunReport` JSON byte for byte
/// with the survival knobs set, at `--nodes 1` and at
/// `--nodes 4 --functions 8`.
#[test]
fn survival_knobs_are_inert_under_other_policies() {
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        // --nodes 1, single-tenant
        {
            let base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 1);
            let trace =
                mpc_serverless::experiments::fig4::trace_for(base.trace, base.duration, base.seed);
            let mut knobs = base.clone();
            knobs.controller.survival = weird_knobs();
            let a = run_experiment(&base, policy, &trace);
            let b = run_experiment(&knobs, policy, &trace);
            assert_eq!(
                canonical_json(a),
                canonical_json(b),
                "{policy:?} must ignore the survival knobs (--nodes 1)"
            );
        }
        // --nodes 4 --functions 8
        {
            let mut base = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 8);
            base.fleet.nodes = 4;
            let w = TenantWorkload::generate(
                base.trace,
                base.duration,
                base.seed,
                8,
                base.tenancy.zipf_s,
                &base.platform,
            );
            let mut knobs = base.clone();
            knobs.controller.survival = weird_knobs();
            let a = run_tenant(&base, policy, &w);
            let b = run_tenant(&knobs, policy, &w);
            assert_eq!(
                canonical_json(a),
                canonical_json(b),
                "{policy:?} must ignore the survival knobs (--nodes 4 --functions 8)"
            );
        }
    }
}

/// Off-policy runs carry no survival telemetry at all — the new report
/// surface is structurally zero on the seed path.
#[test]
fn other_policies_report_structural_survival_zeros() {
    let c = cfg(TraceKind::SyntheticBursty, 900.0, 7, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_experiment(&c, policy, &trace);
        assert_eq!(r.survival_releases, 0, "{policy:?}");
        assert_eq!(r.survival_retained, 0, "{policy:?}");
        assert_eq!(r.survival_mean_p, 0.0, "{policy:?}");
        assert_ne!(r.keepalive_policy, "survival", "{policy:?}");
    }
}

/// Survival runs are deterministic: repeated runs reproduce the full
/// JSON surface, and `--threads 2` is byte-identical to `--threads 1`
/// (the sharded event loop's contract extends to the new policy).
#[test]
fn survival_is_deterministic_across_runs_and_threads() {
    // single-tenant
    {
        let c = cfg(TraceKind::SyntheticBursty, 1200.0, 23, 1);
        let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
        let a = run_experiment(&c, Policy::Survival, &trace);
        let b = run_experiment(&c, Policy::Survival, &trace);
        assert_eq!(canonical_json(a), canonical_json(b));
    }
    // multi-node multi-tenant, across thread counts
    {
        let mut c = cfg(TraceKind::AzureLike, 1200.0, 23, 8);
        c.fleet.nodes = 4;
        let w = TenantWorkload::generate(
            c.trace,
            c.duration,
            c.seed,
            8,
            c.tenancy.zipf_s,
            &c.platform,
        );
        let one = run_tenant(&c, Policy::Survival, &w);
        let mut threaded = c.clone();
        threaded.threads = 2;
        let two = run_tenant(&threaded, Policy::Survival, &w);
        assert_eq!(two.threads, 2);
        assert_eq!(
            canonical_json_any_threads(one),
            canonical_json_any_threads(two),
            "survival must be bit-identical across --threads"
        );
    }
}

/// Release-credit conservation: every survival release is an
/// earlier-than-profile expiry through the shared retention actuator, so
/// the scheduler's release counter must equal the platform's
/// adaptive-expiry counter exactly — and releases must come with idle
/// seconds credited as saved.
#[test]
fn survival_releases_conserve_expiry_credits() {
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 3, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let r = run_experiment(&c, Policy::Survival, &trace);
    assert_eq!(r.dropped, 0);
    assert_eq!(r.keepalive_policy, "survival");
    assert_eq!(
        r.survival_releases, r.counters.adaptive_expiries,
        "scheduler releases out of sync with platform expiries"
    );
    assert!(
        r.survival_releases > 0,
        "the bursty gaps should trigger at least one survival release"
    );
    assert_eq!(r.idle_saved_s > 0.0, r.survival_releases > 0);
    // the estimator actually ran: decisions recorded a probability and a
    // horizon trajectory bounded by floor and profile window
    assert!(r.survival_mean_p > 0.0 && r.survival_mean_p <= 1.0);
    let min_s = c.controller.keepalive.min as f64 / 1e6;
    let max_s = c.platform.keep_alive as f64 / 1e6;
    assert!(
        r.mean_horizon_s >= min_s && r.mean_horizon_s <= max_s,
        "mean horizon {} outside [{min_s}, {max_s}]",
        r.mean_horizon_s
    );
}

/// Threshold extremes bracket the retention behavior: an unbeatable
/// threshold (always release at the floor) must spend strictly less idle
/// resource-time than an always-retain threshold of zero, on the same
/// workload, with no requests lost either way.
#[test]
fn threshold_extremes_order_idle_resource_time() {
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 3, 1);
    let trace = mpc_serverless::experiments::fig4::trace_for(c.trace, c.duration, c.seed);
    let mut eager = c.clone();
    eager.controller.survival.threshold = 1.1; // p <= 1 always fails it
    let mut never = c.clone();
    never.controller.survival.threshold = 0.0; // p < 0 is impossible
    let e = run_experiment(&eager, Policy::Survival, &trace);
    let n = run_experiment(&never, Policy::Survival, &trace);
    assert_eq!(e.dropped, 0);
    assert_eq!(n.dropped, 0);
    assert_eq!(e.completed, n.completed);
    assert!(
        e.idle_total_s < n.idle_total_s,
        "eager-release idle {} !< never-release idle {}",
        e.idle_total_s,
        n.idle_total_s
    );
    assert!(e.survival_releases > 0);
    // never-release keeps every decision at the retain side
    assert_eq!(n.survival_releases, 0);
    assert!(n.survival_retained > 0);
}
