//! Multi-tenant integration tests: the `--functions 1` bit-identical
//! regression that keeps every published figure valid, workload
//! conservation properties, and end-to-end multi-function runs under
//! every policy.

use mpc_serverless::config::{
    secs, ExperimentConfig, PlacementPolicy, Policy, TenantConfig, TraceKind,
};
use mpc_serverless::experiments::{run_experiment, run_tenant};
use mpc_serverless::metrics::RunReport;
use mpc_serverless::workload::tenant::zipf_shares;
use mpc_serverless::workload::{FunctionRegistry, TenantWorkload};

fn cfg(kind: TraceKind, duration_s: f64, seed: u64, functions: u32) -> ExperimentConfig {
    ExperimentConfig {
        trace: kind,
        duration: secs(duration_s),
        seed,
        tenancy: TenantConfig {
            functions,
            zipf_s: 1.1,
        },
        ..Default::default()
    }
}

fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.completed, b.completed, "{ctx}: completed");
    assert_eq!(a.dropped, b.dropped, "{ctx}: dropped");
    assert_eq!(a.mean_ms, b.mean_ms, "{ctx}: mean");
    assert_eq!(a.p50_ms, b.p50_ms, "{ctx}: p50");
    assert_eq!(a.p99_ms, b.p99_ms, "{ctx}: p99");
    assert_eq!(a.counters.cold_starts, b.counters.cold_starts, "{ctx}: cold");
    assert_eq!(a.counters.invocations, b.counters.invocations, "{ctx}: inv");
    assert_eq!(a.warm_series, b.warm_series, "{ctx}: warm series");
    assert_eq!(a.keepalive_total_s, b.keepalive_total_s, "{ctx}: keepalive");
    assert_eq!(a.idle_total_s, b.idle_total_s, "{ctx}: idle");
}

/// The headline regression: a one-function tenant workload through the
/// multi-tenant entry points reproduces the single-tenant path
/// bit-for-bit, for every policy and both trace families.
///
/// Scope note: this pins the tenant *entry points* (generation,
/// registry, runner plumbing) against the trace-based path, which now
/// shares the same event loop — so it cannot, by itself, catch a
/// behavioral drift inside the shared controller code. The true pre-PR
/// reference is `single_node_fleet_matches_legacy_single_platform_exactly`
/// in `integration.rs`, which compares against an inline
/// reimplementation of the pre-fleet event loop; the single-tenant
/// controller paths (`try_dispatch`'s head pop, `force_stale`'s
/// once-per-call imminence) were restored verbatim and are additionally
/// guarded by `bursty_workload_ordering_holds`.
#[test]
fn functions_one_is_bit_identical_to_legacy_single_tenant() {
    for kind in [TraceKind::AzureLike, TraceKind::SyntheticBursty] {
        let c = cfg(kind, 1200.0, 23, 1);
        let trace = mpc_serverless::experiments::fig4::trace_for(kind, c.duration, c.seed);
        let workload = TenantWorkload::generate(kind, c.duration, c.seed, 1, 1.1, &c.platform);
        assert_eq!(workload.arrivals, trace.arrivals, "{kind:?}: trace drift");
        for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
            let legacy = run_experiment(&c, policy, &trace);
            let tenant = run_tenant(&c, policy, &workload);
            assert_reports_identical(&legacy, &tenant, &format!("{kind:?}/{policy:?}"));
            // a single-tenant run can never evict or respawn
            assert_eq!(tenant.counters.evictions, 0);
            assert_eq!(tenant.per_function.len(), 1);
            assert_eq!(tenant.per_function[0].completed, tenant.completed);
        }
    }
}

#[test]
fn multi_tenant_runs_complete_under_every_policy() {
    let functions = 4;
    let c = cfg(TraceKind::SyntheticBursty, 1200.0, 9, functions);
    let w = TenantWorkload::generate(
        TraceKind::SyntheticBursty,
        c.duration,
        c.seed,
        functions,
        1.1,
        &c.platform,
    );
    for policy in [Policy::OpenWhisk, Policy::IceBreaker, Policy::Mpc] {
        let r = run_tenant(&c, policy, &w);
        assert_eq!(r.dropped, 0, "{}: {r:?}", r.policy);
        assert_eq!(r.completed, w.len(), "{}", r.policy);
        // the per-function breakdown partitions the aggregate
        let sum: usize = r.per_function.iter().map(|f| f.completed).sum();
        assert_eq!(sum, r.completed, "{}", r.policy);
        assert!(
            r.per_function.iter().all(|f| (f.func as usize) < functions as usize),
            "{}",
            r.policy
        );
    }
}

#[test]
fn multi_tenant_fleet_with_drain_completes() {
    let mut c = cfg(TraceKind::SyntheticBursty, 1200.0, 31, 4);
    c.fleet.nodes = 4;
    c.fleet.placement = PlacementPolicy::WarmFirst;
    c.fleet.failures = vec![mpc_serverless::config::NodeFailure {
        node: 2,
        at: secs(500.0),
    }];
    let w = TenantWorkload::generate(
        TraceKind::SyntheticBursty,
        c.duration,
        c.seed,
        4,
        1.1,
        &c.platform,
    );
    for policy in [Policy::OpenWhisk, Policy::Mpc] {
        let r = run_tenant(&c, policy, &w);
        assert_eq!(r.dropped, 0, "{}: {r:?}", r.policy);
        assert_eq!(r.completed, w.len(), "{}", r.policy);
        assert_eq!(r.nodes, 4);
    }
}

/// Request shaping + per-function prewarming must reduce cold-start
/// exposure vs the reactive baseline on the contended multi-tenant
/// workload (the bursty trace the paper's headline numbers use).
#[test]
fn mpc_shields_cold_starts_on_multi_tenant_bursty_load() {
    let functions = 8;
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 3, functions);
    let w = TenantWorkload::generate(
        TraceKind::SyntheticBursty,
        c.duration,
        c.seed,
        functions,
        1.1,
        &c.platform,
    );
    let ow = run_tenant(&c, Policy::OpenWhisk, &w);
    let mpc = run_tenant(&c, Policy::Mpc, &w);
    assert_eq!(ow.dropped, 0);
    assert_eq!(mpc.dropped, 0);
    assert!(
        mpc.cold_requests < ow.cold_requests,
        "MPC cold requests {} !< OpenWhisk {}",
        mpc.cold_requests,
        ow.cold_requests
    );
}

#[test]
fn multi_tenant_is_deterministic() {
    let c = cfg(TraceKind::AzureLike, 900.0, 17, 5);
    let w = TenantWorkload::generate(TraceKind::AzureLike, c.duration, c.seed, 5, 1.1, &c.platform);
    let a = run_tenant(&c, Policy::Mpc, &w);
    let b = run_tenant(&c, Policy::Mpc, &w);
    assert_eq!(a.mean_ms, b.mean_ms);
    assert_eq!(a.p99_ms, b.p99_ms);
    assert_eq!(a.counters.cold_starts, b.counters.cold_starts);
    assert_eq!(a.warm_series, b.warm_series);
}

/// Zipf head function dominates traffic, and per-function accounting in
/// the report reflects the skew.
#[test]
fn zipf_skew_shapes_per_function_traffic() {
    let functions = 8;
    let c = cfg(TraceKind::SyntheticBursty, 3600.0, 11, functions);
    let w = TenantWorkload::generate(
        TraceKind::SyntheticBursty,
        c.duration,
        c.seed,
        functions,
        1.1,
        &c.platform,
    );
    let shares = zipf_shares(functions, 1.1);
    let r = run_tenant(&c, Policy::OpenWhisk, &w);
    let head = r.per_function.iter().find(|f| f.func == 0).expect("head");
    let total: usize = r.per_function.iter().map(|f| f.completed).sum();
    let head_share = head.completed as f64 / total as f64;
    // the empirical head share tracks the zipf share (loose tolerance:
    // one bursty trace is a small sample)
    assert!(
        (head_share - shares[0]).abs() < 0.12,
        "head share {head_share:.2} vs zipf {:.2}",
        shares[0]
    );
}

/// A replayed trace keeps its arrival times under tenant assignment and
/// conserves per-bin counts across functions.
#[test]
fn assignment_preserves_arrivals_and_conserves_bins() {
    let pc = ExperimentConfig::default().platform;
    let trace =
        mpc_serverless::experiments::fig4::trace_for(TraceKind::SyntheticBursty, secs(900.0), 5);
    let registry = FunctionRegistry::synthesize(6, 1.1, &pc, 5);
    let w = TenantWorkload::assign(&trace, registry, 5);
    assert_eq!(w.merged().arrivals, trace.arrivals);
    let dt = secs(30.0);
    let merged_bins = w.merged().binned(dt);
    let mut sum = vec![0u32; merged_bins.len()];
    for f in 0..6 {
        for (i, b) in w.per_function(f).binned(dt).iter().enumerate() {
            sum[i] += b;
        }
    }
    assert_eq!(sum, merged_bins);
}
